"""Runtime control plane: detector state machine, SyncPolicy semantics,
ControlPlane closed loop, the policy step cache, and the netsim integration
(persistent-straggler ejection vs wait-for-all, Timely pacing convergence).
"""
import numpy as np
import pytest

from repro.runtime import (ACTIVE, EJECTED, PROBATION, ControlPlane,
                           PolicyStepCache, StepTelemetry, StragglerDetector,
                           SyncPolicy)
from repro.sim.netsim import GASimulator, NetworkModel, simulate_job


def feed(det, times, steps):
    changed = []
    for _ in range(steps):
        changed.append(det.observe(times))
    return changed


class TestStragglerDetector:
    def test_homogeneous_peers_never_ejected(self):
        det = StragglerDetector(8)
        rng = np.random.default_rng(0)
        for _ in range(200):
            det.observe(tuple(rng.lognormal(0.0, 0.15, 8)))
        assert det.active_peers() == tuple(range(8))

    def test_persistent_straggler_ejected_after_patience(self):
        det = StragglerDetector(8, alpha=0.5, patience=3)
        times = (1.0,) * 7 + (6.0,)
        changed = feed(det, times, 10)
        assert det.status(7) == EJECTED
        assert det.active_peers() == tuple(range(7))
        assert any(changed)
        # the EWMA needs a couple of steps to cross, then patience strikes
        assert changed.index(True) >= 2

    def test_probation_then_readmission_when_healed(self):
        det = StragglerDetector(8, alpha=0.5, patience=2, cooldown=3,
                                probation=2)
        feed(det, (1.0,) * 7 + (8.0,), 8)
        assert det.status(7) == EJECTED
        # peer heals: after the cooldown it re-enters on probation, and
        # `probation` clean steps promote it back to ACTIVE
        healed = (1.0,) * 8
        seen = set()
        for _ in range(12):
            det.observe(healed)
            seen.add(det.status(7))
            if det.status(7) == ACTIVE:
                break
        assert PROBATION in seen
        assert det.status(7) == ACTIVE
        assert det.active_peers() == tuple(range(8))

    def test_reejection_from_probation_backs_off(self):
        det = StragglerDetector(8, alpha=0.5, patience=2, cooldown=2,
                                probation=2)
        slow = (1.0,) * 7 + (8.0,)
        feed(det, slow, 40)
        p = det.peers[7]
        assert p.status == EJECTED
        assert p.ejections >= 2
        # exponential backoff: the second cooldown is longer than the first
        assert p.countdown > det.cooldown or p.ejections > 2

    def test_min_active_floor(self):
        det = StragglerDetector(3, alpha=1.0, patience=1, min_active=2,
                                cooldown=100)
        feed(det, (1.0, 1.0, 9.0), 5)
        assert det.status(2) == EJECTED
        # a second peer degrades, but ejecting it would drop below the
        # floor — it stays active however slow it scores
        feed(det, (1.0, 9.0, 9.0), 10)
        assert len(det.active_peers()) == 2
        assert det.status(1) == ACTIVE
        assert det.peers[1].score > det.eject_score

    def test_disabled_never_ejects(self):
        det = StragglerDetector(8, enabled=False, alpha=1.0, patience=1)
        feed(det, (1.0,) * 7 + (50.0,), 20)
        assert det.active_peers() == tuple(range(8))
        assert det.peers[7].score > 10     # still scored, just not acted on

    def test_probation_counts_as_participating(self):
        det = StragglerDetector(4, alpha=1.0, patience=1, cooldown=2)
        det.observe((1.0, 1.0, 1.0, 9.0))
        assert det.status(3) == EJECTED
        det.observe((1.0, 1.0, 1.0, 1.0))      # countdown 2 -> 1
        assert det.status(3) == EJECTED
        det.observe((1.0, 1.0, 1.0, 1.0))      # countdown -> 0: probation
        assert det.status(3) == PROBATION
        assert 3 in det.active_peers()


class TestSyncPolicy:
    def test_hashable_and_timeout_x_excluded(self):
        a = SyncPolicy(use_hadamard=True, incast=2, active_peers=(0, 1, 2),
                       timeout_x=0.10)
        b = SyncPolicy(use_hadamard=True, incast=2, active_peers=(0, 1, 2),
                       timeout_x=0.37)
        assert a == b and hash(a) == hash(b)
        assert a.compile_key == b.compile_key
        assert a != SyncPolicy(use_hadamard=True, incast=2,
                               active_peers=None)

    def test_apply_folds_into_cfg(self):
        from repro.core import OptiReduceConfig
        cfg = OptiReduceConfig(strategy="optireduce_rounds")
        p = SyncPolicy(use_hadamard=True, incast=3, active_peers=(0, 1, 3))
        out = p.apply(cfg)
        assert out.use_hadamard and out.incast == 3
        assert out.active_peers == (0, 1, 3)
        assert out.strategy == cfg.strategy


class TestControlPlane:
    def test_policy_closed_loop_with_ejection(self):
        cp = ControlPlane.create(n_nodes=8,
                                 detector_kw=dict(alpha=0.5, patience=2))
        for s in range(30):
            cp.observe(StepTelemetry(
                step=s, loss_frac=0.0,
                peer_stage_times=(1.0,) * 7 + (7.0,)))
        pol = cp.policy()
        assert pol.active_peers == tuple(range(7))
        # incast is clamped to the active-set fan-in
        assert pol.incast <= len(pol.active_peers) - 1

    def test_hadamard_hysteresis(self):
        cp = ControlPlane.create(n_nodes=8)
        cp.observe(StepTelemetry(loss_frac=0.05))      # above 2%: on
        assert cp.policy().use_hadamard
        cp.observe(StepTelemetry(loss_frac=0.015))     # in the band: hold
        assert cp.policy().use_hadamard
        cp.observe(StepTelemetry(loss_frac=0.001))     # below thr/2: off
        assert not cp.policy().use_hadamard

    def test_warmup_feeds_timeout(self):
        cp = ControlPlane.create(n_nodes=4, timeout={"warmup_iters": 3})
        for t in (1.0, 2.0, 3.0):
            cp.observe(StepTelemetry(step_time=t))
        assert cp.state.timeout.ready

    def test_observe_reports_policy_movement(self):
        cp = ControlPlane.create(n_nodes=8)
        assert cp.observe(StepTelemetry(loss_frac=0.05))   # HT flips on
        # same telemetry again: I ramps are gone (halved already at floor)?
        # incast halves 1 -> 1 (floor) and HT stays: no movement
        assert not cp.observe(StepTelemetry(loss_frac=0.05))


class TestPolicyStepCache:
    def test_lru_hit_and_eviction(self):
        cache = PolicyStepCache(maxsize=2)
        p1 = SyncPolicy(incast=1)
        p2 = SyncPolicy(incast=2)
        p3 = SyncPolicy(incast=3)
        cache.put(p1, "a")
        cache.put(p2, "b")
        assert cache.get(p1) == "a"                    # p1 now most-recent
        cache.put(p3, "c")                             # evicts p2
        assert cache.get(p2) is None
        assert cache.get(p1) == "a" and cache.get(p3) == "c"
        assert len(cache) == 2

    def test_eject_readmit_cycle_never_recompiles(self):
        cache = PolicyStepCache(maxsize=4)
        full = SyncPolicy(active_peers=None)
        degraded = SyncPolicy(active_peers=tuple(range(7)))
        cache.put(full, "full-step")
        cache.put(degraded, "degraded-step")
        # eject -> readmit -> eject again: every switch is a cache hit
        for pol in (degraded, full, degraded, full):
            assert cache.get(pol) is not None
        assert cache.misses == 0 and cache.hits == 4

    def test_timeout_x_drift_is_not_a_miss(self):
        cache = PolicyStepCache()
        cache.put(SyncPolicy(incast=2, timeout_x=0.10), "step")
        assert cache.get(SyncPolicy(incast=2, timeout_x=0.50)) == "step"


# --------------------------------------------------------------- netsim loop
def _straggler_run(eject: bool, steps: int = 120, factor: float = 8.0,
                   seed: int = 5):
    env = NetworkModel.environment("local_1.5", seed=seed)
    env.peer_factors = (1.0,) * 7 + (factor,)
    control = ControlPlane.create(n_nodes=8, detect_stragglers=eject)
    r = simulate_job("optireduce", n_nodes=8, bucket_bytes=25 * 2 ** 20,
                     n_steps=steps, env=env, compute_ms=0.0, overlap=0.0,
                     eject_stragglers=eject, control=control)
    return r, control


def test_ejection_beats_wait_for_all_bounded_drops():
    """Acceptance: a simulated persistent-straggler run shows ejection
    beating wait-for-all on median step time while the effective transport
    drop fraction stays bounded (the straggler's share is *excluded*, not
    lost — the masked mean renormalizes over active peers)."""
    wait, _ = _straggler_run(eject=False)
    ej, control = _straggler_run(eject=True)
    assert ej["p50_ga_ms"] < 0.5 * wait["p50_ga_ms"], (ej["p50_ga_ms"],
                                                       wait["p50_ga_ms"])
    assert 0.0 <= ej["mean_drop"] < 0.01
    # exactly the slow peer was ejected, nobody else
    assert control.detector.peers[7].ejections >= 1
    assert all(p.ejections == 0 for p in control.detector.peers[:7])
    assert set(ej["active_peers"]) <= set(range(8))


def test_no_straggler_no_ejection():
    """Homogeneous peers: arming the detector must not change membership."""
    env = NetworkModel.environment("local_1.5", seed=9)
    control = ControlPlane.create(n_nodes=8, detect_stragglers=True)
    r = simulate_job("optireduce", n_nodes=8, bucket_bytes=25 * 2 ** 20,
                     n_steps=80, env=env, compute_ms=0.0, overlap=0.0,
                     eject_stragglers=True, control=control)
    assert r["active_peers"] == list(range(8))
    assert r["ejected_peers"] == []


def test_timely_pacing_converges_under_sustained_congestion():
    """Satellite: the §3.2.3 Timely controller, wired into the simulator's
    flow pacing, converges to the bottleneck's fair share under sustained
    congestion (8 flows into a 8 Gbps bottleneck -> ~1 Gbps each) and
    drains the queue it built while overloaded."""
    env = NetworkModel.environment("local_1.5", seed=3)
    sim = GASimulator(env, 8, pace=True, capacity_GBps=1.0)
    rates, delays = [], []
    for _ in range(400):
        delays.append(sim.paced_round_delay_s(3.3e6, 8))
        rates.append(sim.pacer.rate)
    share = 1.0 * 8e9 / 8
    tail = np.asarray(rates[-100:])
    assert rates[0] > 2 * share                 # started well above share
    assert 0.5 * share < tail.mean() < 1.5 * share
    assert float(np.mean(delays[-100:])) < 0.1 * max(delays)  # queue drained


def test_paced_optireduce_still_progresses():
    """Pacing in the UBT datapath: optireduce steps complete with finite
    times and bounded drops when pace=True."""
    env = NetworkModel.environment("local_3.0", seed=4)
    r = simulate_job("optireduce", n_nodes=8, bucket_bytes=25 * 2 ** 20,
                     n_steps=40, env=env, compute_ms=0.0, overlap=0.0,
                     pace=True)
    assert np.isfinite(r["mean_ga_ms"]) and r["mean_ga_ms"] > 0
    assert 0.0 <= r["mean_drop"] < 0.02


def test_adaptive_transport_is_thin_adapter():
    """AdaptiveTransport delegates to the ControlPlane: per-peer stage
    times flow through to the detector and apply() carries the policy's
    active set into the config."""
    from repro.core import OptiReduceConfig
    from repro.core.pipeline import AdaptiveTransport
    at = AdaptiveTransport.create(n_nodes=8,
                                  detector_kw=dict(alpha=0.5, patience=2))
    for _ in range(20):
        at.observe(0.0, peer_stage_times=(1.0,) * 7 + (9.0,))
    assert at.control.detector.status(7) == EJECTED
    cfg = at.apply(OptiReduceConfig(strategy="optireduce_rounds"))
    assert cfg.active_peers == tuple(range(7))


# --------------------------------------- phase-aware loss budget (DESIGN §8)
class TestLossBudget:
    def test_budget_monotone_in_phase(self):
        from repro.core.ubt import LossBudget
        b = LossBudget()
        vals = []
        for k in range(6):
            b.update_phase(progress=k / 5.0)
            vals.append(b.budget())
        assert vals[0] == pytest.approx(b.budget_init)
        assert vals[-1] == pytest.approx(b.budget_final)
        assert all(x > y for x, y in zip(vals, vals[1:]))
        # the phase never moves backward, even if the signal does
        b.update_phase(progress=0.1)
        assert b.budget() == pytest.approx(b.budget_final)

    def test_plateau_detector_advances_phase(self):
        from repro.core.ubt import LossBudget
        b = LossBudget(plateau_patience=4)
        for _ in range(5):     # first feed only seeds the best-loss tracker
            assert b.update_phase(train_loss=5.0) <= 1.0
        assert b.phase == pytest.approx(1.0)
        # an improving curve keeps the phase down
        c = LossBudget(plateau_patience=4)
        loss = 5.0
        for _ in range(8):
            c.update_phase(train_loss=loss)
            loss *= 0.9
        assert c.phase < 0.5

    def test_accept_or_extend_stretch(self):
        from repro.core.ubt import LossBudget
        b = LossBudget()
        b.observe(0.001)                      # under the phase-0 budget
        assert b.deadline_factor() == 1.0
        b.update_phase(progress=1.0)          # tighten to budget_final
        assert b.over_budget()
        f = b.deadline_factor()
        assert 1.0 < f <= b.max_stretch
        assert b.stretch(10.0) == pytest.approx(10.0 * f)
        assert b.stretch(10.0, hard=12.0) == 12.0

    def test_budget_tightens_accepted_drops_over_lr_decay(self):
        """Acceptance: under a *constant* lossy network, the budget turns
        simulated LR decay into a falling accepted-drop fraction — late
        training waits for late packets instead of charging them as drops
        (accept-or-extend), while the no-budget control stays flat."""
        from repro.sim.netsim import GASimulator, NetworkModel

        def run(with_budget: bool):
            # heavy-tail, no byte-shedding: every drop is a deadline
            # truncation, i.e. recoverable by waiting — what the budget
            # trades tail latency for
            env = NetworkModel(median_ms=1.0, p99_over_p50=4.0,
                               stall_prob=0.0, seed=11)
            sim = GASimulator(env, 8)
            kw = {"budget": {}} if with_budget else {}
            control = ControlPlane.create(
                n_nodes=8, detect_stragglers=False,
                timeout={"x_init": 0.02, "x_max": 0.05,
                         "warmup_iters": 20}, **kw)
            control = sim.warmup(1e6, control=control)
            steps = 60
            drops = []
            for s in range(steps):
                r = sim.optireduce(1e6, control, fixed_incast=1)
                drops.append(r.drop_frac)
                if with_budget:
                    control.state.budget.update_phase(
                        progress=(s + 1) / steps)
            return np.asarray(drops)

        budgeted = run(True)
        flat = run(False)
        early = float(np.mean(budgeted[:15]))
        late = float(np.mean(budgeted[-15:]))
        # same network, but the tightened budget stretches deadlines: the
        # accepted drop fraction falls materially across the decay
        assert late < 0.5 * max(early, 1e-12)
        # and clearly below the unbudgeted control's late-phase drops
        assert late < 0.5 * float(np.mean(flat[-15:]))


class TestShardWeights:
    def test_homogeneous_peers_uniform_weights(self):
        det = StragglerDetector(8)
        rng = np.random.default_rng(1)
        for _ in range(50):
            det.observe(tuple(rng.lognormal(0.0, 0.1, 8)))
        assert det.weights() == (det.weight_resolution,) * 8

    def test_straggler_weight_reduced_floor_clamped(self):
        # enabled=False: scoring continues but nothing is ever ejected —
        # isolates the weight path from the ejection state machine
        det = StragglerDetector(8, alpha=0.5, enabled=False)
        for _ in range(30):
            det.observe((1.0,) * 7 + (6.0,))
        w = det.weights()
        res = det.weight_resolution
        floor = max(1, round(det.weight_floor * res))
        assert w[7] < res                   # reduced...
        assert w[7] >= floor                # ...but floor-clamped, not zero
        assert w[:7] == (res,) * 7          # fast peers keep full weight

    def test_ejected_zero_probation_reduced_not_zero(self):
        det = StragglerDetector(8, alpha=0.5, patience=2, cooldown=2,
                                probation=6)
        feed(det, (1.0,) * 7 + (8.0,), 8)
        assert det.status(7) == EJECTED
        assert det.weights()[7] == 0        # ejected: no shard at all
        healed = (1.0,) * 8
        for _ in range(10):
            det.observe(healed)
            if det.status(7) == PROBATION:
                break
        assert det.status(7) == PROBATION
        w7 = det.weights()[7]
        # PROBATION: watched, not trusted — reduced (half-weight cap,
        # re-entering at the floor), but NEVER zero
        assert 0 < w7 <= max(1, det.weight_resolution // 2)

    def test_hysteresis_band_stops_weight_thrash(self):
        # a score dithering around a unit boundary must not flip the
        # weight tuple every step (each distinct tuple is a recompile)
        det = StragglerDetector(4, alpha=1.0, enabled=False)
        det.observe((1.0, 1.0, 1.0, 1.35))
        seen = {det.weights()}
        for i in range(40):
            det.observe((1.0, 1.0, 1.0, 1.3 + 0.1 * (i % 2)))
            seen.add(det.weights())
        assert len(seen) == 1


class TestLinkHealth:
    @staticmethod
    def _tele(step, events):
        return StepTelemetry(step=step, loss_frac=0.0, step_time=10.0,
                             dead_link_events=tuple(events))

    def test_patience_then_dead_then_probe_recovery(self):
        cp = ControlPlane.create(n_nodes=4, link_patience=2, link_recover=3)
        cp.observe(self._tele(0, [(1, 2)]))
        assert cp.dead_links() == ()                   # one strike only
        cp.observe(self._tele(1, [(1, 2)]))
        assert cp.dead_links() == ((1, 2),)
        assert cp.policy().dead_links == ((1, 2),)
        # once dead the schedule relays around the edge, so it goes
        # unobserved; link_recover quiet steps revive it (a probe)
        for s in range(3):
            cp.observe(self._tele(2 + s, []))
        assert cp.dead_links() == ()

    def test_clean_observation_clears_strikes(self):
        cp = ControlPlane.create(n_nodes=4, link_patience=2)
        cp.observe(self._tele(0, [(1, 2)]))
        cp.observe(self._tele(1, []))       # clean step: strikes reset
        cp.observe(self._tele(2, [(1, 2)]))
        assert cp.dead_links() == ()

    def test_policy_filters_links_to_members(self):
        cp = ControlPlane.create(n_nodes=4, link_patience=1)
        cp.detector.force_eject(3)
        cp.observe(self._tele(0, [(1, 3), (0, 2)]))
        # the tracker remembers both; the policy only advertises edges
        # between *active* peers (ejected endpoints have no schedule)
        assert cp.dead_links() == ((0, 2), (1, 3))
        assert cp.policy().dead_links == ((0, 2),)


class TestRebalancePolicy:
    def test_uniform_weights_normalize_to_none(self):
        cp = ControlPlane.create(n_nodes=4, rebalance=True)
        for _ in range(10):
            cp.observe(StepTelemetry(
                step=0, loss_frac=0.0,
                peer_stage_times=(1.0, 1.0, 1.0, 1.0)))
        # bitwise-parity pin: homogeneous peers emit shard_weights=None,
        # not a uniform tuple — the full-participation trace is unchanged
        assert cp.policy().shard_weights is None

    def test_straggler_gets_reduced_weight_without_ejection(self):
        cp = ControlPlane.create(n_nodes=4, rebalance=True,
                                 detect_stragglers=False)
        for _ in range(30):
            cp.observe(StepTelemetry(
                step=0, loss_frac=0.0,
                peer_stage_times=(1.0, 1.0, 1.0, 5.0)))
        w = cp.policy().shard_weights
        assert w is not None
        assert 1 <= w[3] < w[0]
        assert cp.policy().active_peers is None        # nobody ejected

    def test_compile_key_covers_weights_and_links(self):
        a = SyncPolicy()
        b = SyncPolicy(shard_weights=(2, 1, 2, 2))
        c = SyncPolicy(dead_links=((0, 1),))
        assert len({a.compile_key, b.compile_key, c.compile_key}) == 3
        cache = PolicyStepCache(maxsize=4)
        cache.put(b, "weighted")
        assert cache.get(a) is None
        assert cache.get(b) == "weighted"

    def test_apply_folds_weights_and_links_into_cfg(self):
        from repro.core.pipeline import OptiReduceConfig
        pol = SyncPolicy(shard_weights=(2, 1, 2, 2),
                         dead_links=((0, 3),))
        cfg = pol.apply(OptiReduceConfig(strategy="optireduce_rounds"))
        assert cfg.shard_weights == (2, 1, 2, 2)
        assert cfg.dead_links == ((0, 3),)


def test_rebalance_within_15pct_of_ejection_with_contribution():
    """ISSUE 8 acceptance: under a persistent 6x straggler,
    straggler-proportional rebalancing holds the median step time within
    15% of outright ejection while the straggler's gradient contribution
    stays nonzero (ejection zeroes it) and the straggler is never ejected."""
    def run(mode):
        env = NetworkModel(p99_over_p50=1.5, stall_prob=0.01, seed=7)
        n = 8
        env.peer_factors = (1.0,) * 3 + (6.0,) + (1.0,) * (n - 4)
        sim = GASimulator(env, n)
        nbytes = 25 * 2 ** 20
        control = ControlPlane.create(n_nodes=n,
                                      detect_stragglers=(mode == "eject"),
                                      rebalance=(mode == "rebalance"))
        sim.warmup(nbytes, control=control)
        times, contribs = [], []
        for _ in range(60):
            r = sim.optireduce(nbytes, control, fixed_incast=4)
            times.append(r.time_ms)
            if r.peer_contrib is not None:
                contribs.append(r.peer_contrib[3])
        return float(np.median(times[30:])), contribs, control

    t_ej, _, ctl_e = run("eject")
    t_rb, contribs, ctl_r = run("rebalance")
    assert ctl_e.detector.ejected_peers() == (3,)     # ejection arm ejects
    assert ctl_r.detector.ejected_peers() == ()       # rebalance never does
    w = ctl_r.detector.weights()
    assert w[3] < w[0]                                # smaller slice instead
    assert t_rb <= 1.15 * t_ej
    # the whole point: the slow peer still contributes gradient mass
    assert contribs
    assert float(np.mean(contribs[-20:])) > 0.05


class TestTelemetryNaNEdges:
    """Regression (ISSUE 10 satellite): telemetry folds must survive empty
    exchanges — no-observation reports, all-NaN peer columns, zero-length
    round lists — without warnings and without perturbing detector state."""

    def _fold(self, reports, step):
        from repro.net.host_ring import aggregate_reports
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("error")      # an All-NaN nanmax warns
            return aggregate_reports(reports, step=step)

    def test_aggregate_empty_report_list(self):
        t = self._fold([], step=3)
        assert t.step == 3
        assert t.peer_stage_times is None
        assert t.round_times == ()
        assert t.step_time is None
        assert not t.timed_out

    def test_aggregate_report_without_observations(self):
        from repro.net.peer import PeerReport
        t = self._fold([PeerReport()], step=4)
        assert t.peer_stage_times is None
        assert t.round_times == ()

    def test_aggregate_all_nan_peer_column_no_warning(self):
        from repro.net.peer import PeerReport, RoundReport
        reps = []
        for _ in range(2):
            r = PeerReport(sender_last_t=np.array([1.0, np.nan, 2.0]))
            r.rounds.append(RoundReport(time=0.5, timed_out=False,
                                        frac_received=1.0))
            reps.append(r)
        t = self._fold(reps, step=1)
        assert t.peer_stage_times is not None
        assert t.peer_stage_times[0] == 1.0
        assert np.isnan(t.peer_stage_times[1])    # unobserved stays NaN
        assert t.peer_stage_times[2] == 2.0

    def test_from_wire_passes_none_peer_times_through(self):
        t = StepTelemetry.from_wire(step=0, round_times=(),
                                    round_timed_out=(),
                                    round_frac_received=(),
                                    peer_stage_times=None,
                                    dropped=0.0, total=0.0)
        assert t.peer_stage_times is None
        assert t.loss_frac == 0.0 and not t.timed_out

    def test_control_plane_holds_state_on_missing_input(self):
        """A step with no observations must not move the detector or the
        policy — controllers with missing inputs hold."""
        plane = ControlPlane.create(4, detector_kw=dict(alpha=0.5,
                                                        patience=2))
        # push peer 3 toward ejection, then feed empty telemetry
        for step in range(3):
            plane.observe(StepTelemetry(step=step, loss_frac=0.0,
                                        peer_stage_times=(1., 1., 1., 5.)))
        scores = tuple(p.score for p in plane.detector.peers)
        statuses = tuple(p.status for p in plane.detector.peers)
        pol = plane.policy()
        empty = self._fold([], step=3)
        assert plane.observe(empty) is False
        assert tuple(p.score for p in plane.detector.peers) == scores
        assert tuple(p.status for p in plane.detector.peers) == statuses
        assert plane.policy() == pol

    def test_all_nan_column_through_observe_no_warning(self):
        import warnings
        plane = ControlPlane.create(3)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            for step in range(5):
                plane.observe(StepTelemetry(
                    step=step, loss_frac=0.0,
                    peer_stage_times=(1.0, float("nan"), 1.0)))
        # the NaN peer is unobserved, not a straggler: never ejected
        assert plane.detector.active_peers() == (0, 1, 2)
