"""End-to-end behaviour tests for the OptiReduce system on a single device
(multi-device paths are covered by tests/test_collectives.py)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core.allreduce import OptiReduceConfig
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.optim.optimizers import OptimizerConfig
from repro.train.trainer import TrainConfig, build_train_step


def _setup(strategy="optireduce", drop_rate=0.0, dp_mode="replicated"):
    cfg = get_smoke("gpt2-paper")
    mesh = make_host_mesh(dp=1, tp=1)
    tc = TrainConfig(
        sync=OptiReduceConfig(strategy=strategy, drop_rate=drop_rate,
                              hadamard_block=256),
        optimizer=OptimizerConfig(lr=5e-3),
        dp_mode=dp_mode, seq_chunk=16)
    make_step, opt, _ = build_train_step(cfg, tc, mesh)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = {"tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (4, 16), 0, cfg.vocab_size)}
    step_fn, sh = make_step(jax.eval_shape(opt.init, params), batch)
    params = jax.device_put(params, sh["params"])
    opt_state = jax.jit(opt.init, out_shardings=sh["opt"])(params)
    batch = jax.device_put(batch, sh["batch"])
    return jax.jit(step_fn), params, opt_state, batch


def test_training_reduces_loss():
    jf, params, opt_state, batch = _setup()
    key = jax.random.PRNGKey(0)
    losses = []
    for i in range(6):
        params, opt_state, m = jf(params, opt_state, batch,
                                  jnp.asarray(i, jnp.int32), key)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert not any(np.isnan(losses))


def test_metrics_reported():
    jf, params, opt_state, batch = _setup()
    _, _, m = jf(params, opt_state, batch, jnp.zeros((), jnp.int32),
                 jax.random.PRNGKey(0))
    for k in ("loss", "grad_norm", "loss_frac", "skipped"):
        assert k in m
    assert float(m["loss_frac"]) == 0.0   # single worker: nothing to drop


def test_strategies_agree_single_worker():
    """With dp=1 every strategy degenerates to the identity — a coherence
    check of the whole strategy dispatch plumbing."""
    key = jax.random.PRNGKey(0)
    results = {}
    for s in ("psum", "tar_tcp", "optireduce"):
        jf, params, opt_state, batch = _setup(strategy=s)
        _, _, m = jf(params, opt_state, batch, jnp.zeros((), jnp.int32), key)
        results[s] = float(m["loss"])
    vals = list(results.values())
    np.testing.assert_allclose(vals, vals[0], rtol=1e-5)
