"""End-to-end behaviour tests for the OptiReduce system on a single device
(multi-device paths are covered by tests/test_collectives.py)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core.allreduce import OptiReduceConfig
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.optim.optimizers import OptimizerConfig
from repro.train.trainer import TrainConfig, build_train_step


def _setup(strategy="optireduce", drop_rate=0.0, dp_mode="replicated",
           **tc_kw):
    cfg = get_smoke("gpt2-paper")
    mesh = make_host_mesh(dp=1, tp=1)
    tc = TrainConfig(
        sync=OptiReduceConfig(strategy=strategy, drop_rate=drop_rate,
                              hadamard_block=256),
        optimizer=OptimizerConfig(lr=5e-3),
        dp_mode=dp_mode, seq_chunk=16, **tc_kw)
    make_step, opt, _ = build_train_step(cfg, tc, mesh)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = {"tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (4, 16), 0, cfg.vocab_size)}
    step_fn, sh = make_step(jax.eval_shape(opt.init, params), batch)
    params = jax.device_put(params, sh["params"])
    opt_state = jax.jit(opt.init, out_shardings=sh["opt"])(params)
    batch = jax.device_put(batch, sh["batch"])
    return jax.jit(step_fn), params, opt_state, batch


def test_training_reduces_loss():
    jf, params, opt_state, batch = _setup()
    key = jax.random.PRNGKey(0)
    losses = []
    for i in range(6):
        params, opt_state, m = jf(params, opt_state, batch,
                                  jnp.asarray(i, jnp.int32), key)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert not any(np.isnan(losses))


def test_metrics_reported():
    jf, params, opt_state, batch = _setup()
    _, _, m = jf(params, opt_state, batch, jnp.zeros((), jnp.int32),
                 jax.random.PRNGKey(0))
    for k in ("loss", "grad_norm", "loss_frac", "skipped"):
        assert k in m
    assert float(m["loss_frac"]) == 0.0   # single worker: nothing to drop


def test_sync_modes_agree():
    """scan / vmap / pipelined bucket schedules produce the same step (the
    engines are bitwise-identical; the whole trainer step must agree too)."""
    key = jax.random.PRNGKey(0)
    metrics = {}
    for mode in ("pipelined", "scan", "vmap"):
        jf, params, opt_state, batch = _setup(sync_mode=mode)
        _, _, m = jf(params, opt_state, batch, jnp.zeros((), jnp.int32), key)
        metrics[mode] = (float(m["loss"]), float(m["grad_norm"]))
    assert metrics["pipelined"] == metrics["scan"] == metrics["vmap"], metrics


def test_microbatched_arena_matches_full_batch_direction():
    """Grad accumulation through the packed arena: the micro-batched step
    runs, reports the mean loss of the microbatches, and lands near the
    full-batch step (equal-size microbatches of a linear mean)."""
    key = jax.random.PRNGKey(0)
    jf_full, params, opt_state, batch = _setup()
    _, _, m_full = jf_full(params, opt_state, batch,
                           jnp.zeros((), jnp.int32), key)
    jf_mb, params, opt_state, batch = _setup(microbatch=2)
    p2, o2, m_mb = jf_mb(params, opt_state, batch,
                         jnp.zeros((), jnp.int32), key)
    np.testing.assert_allclose(float(m_mb["loss"]), float(m_full["loss"]),
                               rtol=1e-5)
    np.testing.assert_allclose(float(m_mb["grad_norm"]),
                               float(m_full["grad_norm"]), rtol=1e-3)
    assert np.isfinite(float(m_mb["grad_norm"]))


def test_strategies_agree_single_worker():
    """With dp=1 every strategy degenerates to the identity — a coherence
    check of the whole strategy dispatch plumbing."""
    key = jax.random.PRNGKey(0)
    results = {}
    for s in ("psum", "tar_tcp", "optireduce"):
        jf, params, opt_state, batch = _setup(strategy=s)
        _, _, m = jf(params, opt_state, batch, jnp.zeros((), jnp.int32), key)
        results[s] = float(m["loss"])
    vals = list(results.values())
    np.testing.assert_allclose(vals, vals[0], rtol=1e-5)
