"""Dry-run tooling: HLO collective parsing and the linear cost model."""
import pytest

from repro.launch.dryrun import _shape_bytes, parse_collective_bytes

HLO = """
ENTRY %main {
  %ag = bf16[16,256]{1,0} all-gather(bf16[1,256]{1,0} %x), replica_groups={}
  %ar.1 = f32[1024]{0} all-reduce(f32[1024]{0} %y), to_apply=%add
  %aa = (bf16[8,64]{1,0}, u8[128]{0}) all-to-all(bf16[8,64]{1,0} %z)
  %cp-start = bf16[32]{0} collective-permute-start(bf16[32]{0} %w)
  %cp-done = bf16[32]{0} collective-permute-done(bf16[32]{0} %cp-start)
  %rs = f32[64]{0} reduce-scatter(f32[512]{0} %v)
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[16,256]") == 16 * 256 * 2
    assert _shape_bytes("f32[1024]") == 4096
    assert _shape_bytes("(bf16[8,64], u8[128])") == 8 * 64 * 2 + 128


def test_parse_collectives():
    out = parse_collective_bytes(HLO)
    assert out["all-gather"] == 16 * 256 * 2
    assert out["all-reduce"] == 4096
    assert out["all-to-all"] == 8 * 64 * 2 + 128
    assert out["collective-permute"] == 64      # start counted, done skipped
    assert out["reduce-scatter"] == 256
    assert out["count_all-gather"] == 1


def test_cost_model_linear_fit():
    """The 4-point fit must recover an exactly affine metric."""
    d1, d2, b1, b2 = 1, 2, 16, 32
    L, B = 64, 256
    fix_base, tok_base, fix_layer, tok_layer = 5.0, 3.0, 7.0, 11.0

    def m(d, b):
        return fix_base + b * tok_base + d * (fix_layer + b * tok_layer)

    lay_b1 = (m(d2, b1) - m(d1, b1)) / (d2 - d1)
    lay_b2 = (m(d2, b2) - m(d1, b2)) / (d2 - d1)
    tl = (lay_b2 - lay_b1) / (b2 - b1)
    fl = lay_b1 - b1 * tl
    base_b1 = m(d1, b1) - d1 * lay_b1
    base_b2 = m(d1, b2) - d1 * lay_b2
    tb = (base_b2 - base_b1) / (b2 - b1)
    fb = base_b1 - b1 * tb
    val = fb + B * tb + L * (fl + B * tl)
    assert val == pytest.approx(m(L, B))
