"""core.hadamard: randomized HT over buckets — roundtrip, linearity,
drop-dispersal (Fig 9 property)."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, strategies as st
except ImportError:
    from _hypothesis_fallback import given, strategies as st

from repro.core.hadamard import ht_decode, ht_encode, rademacher_sign


def test_roundtrip():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8192,))
    y = ht_decode(ht_encode(x, key, block=1024), key, block=1024)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-4)


def test_linearity_mean_commutes():
    key = jax.random.PRNGKey(1)
    xs = jax.random.normal(key, (8, 4096))
    enc = jax.vmap(lambda v: ht_encode(v, key, block=1024))(xs)
    dec = ht_decode(jnp.mean(enc, 0), key, block=1024)
    np.testing.assert_allclose(np.asarray(dec),
                               np.asarray(jnp.mean(xs, 0)), atol=1e-4)


@given(st.integers(0, 2**31 - 1))
def test_tail_drop_dispersal(seed):
    """Dropping the tail of an encoded bucket produces LOWER max-coordinate
    error than dropping the raw tail (error spread across the block).

    The raw tail must carry real mass for the comparison to be meaningful
    (if the tail happens to hold only near-zero values, dropping it raw is
    harmless by luck), so a spike is planted inside the dropped region —
    the Fig 9 scenario."""
    key = jax.random.PRNGKey(seed)
    block = 1024
    x = jax.random.normal(key, (block,))
    x = x.at[-3].set(12.0)               # heavy coordinate in the tail
    keep = jnp.arange(block) < int(block * 0.9)
    raw = jnp.where(keep, x, 0.0)
    enc = ht_encode(x, key, block=block)
    dec = ht_decode(jnp.where(keep, enc, 0.0) / 0.9, key, block=block)
    max_err_raw = float(jnp.max(jnp.abs(raw - x)))
    max_err_ht = float(jnp.max(jnp.abs(dec - x)))
    assert max_err_ht < max_err_raw


def test_sign_deterministic():
    s1 = rademacher_sign(jax.random.PRNGKey(5), 256)
    s2 = rademacher_sign(jax.random.PRNGKey(5), 256)
    assert jnp.array_equal(s1, s2)
    assert set(np.unique(np.asarray(s1))) <= {-1.0, 1.0}
