"""CI smoke for the observability bench: ``python -m benchmarks.run --only
bench_obs`` in quick mode must keep producing the overhead rows the
PR-over-PR trajectory diffs (and the DESIGN §12 overhead contract) consume
— the disabled-gate / enabled-record / histogram primitives and the traced
vs untraced wire-step pair, each median with its ``_iqr_us`` dispersion
sibling.

Writes to a tmpdir via ``REPRO_BENCH_DIR`` so a test run never rewrites the
checked-in BENCH_obs.json baseline.
"""
import json
import math
import os
import subprocess
import sys

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_MEDIANS = (
    "obs/disabled_gate_median_us",
    "obs/enabled_complete_median_us",
    "obs/enabled_event_median_us",
    "obs/hist_record_median_us",
    "obs/wire_step_untraced_median_us",
    "obs/wire_step_traced_median_us",
)


@pytest.mark.slow
def test_bench_obs_quick_schema(tmp_path):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_TRACE", None)          # the bench manages tracing itself
    src = os.path.join(_REPO, "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [_REPO, src, env.get("PYTHONPATH", "")])
    env["REPRO_BENCH_DIR"] = str(tmp_path)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "bench_obs"],
        env=env, cwd=_REPO, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "FAILED" not in proc.stdout, proc.stdout

    path = tmp_path / "BENCH_obs.json"
    assert path.exists(), "run.py did not honor REPRO_BENCH_DIR"
    payload = json.loads(path.read_text())
    assert payload["_meta"] == {"mode": "quick", "bench": "bench_obs"}

    keys = set(payload) - {"_meta"}
    for key in _MEDIANS:
        assert key in keys, key
        sibling = key[:-len("_median_us")] + "_iqr_us"
        assert sibling in keys, sibling
    assert "obs/wire_step_overhead_pct" in keys
    for key in keys:
        value = payload[key]["value"]
        assert isinstance(value, (int, float)) and math.isfinite(value), key
    # overhead contract sanity: the disabled gate is sub-microsecond per
    # call site even on a loaded CI box (the design budget is tens of ns)
    assert payload["obs/disabled_gate_median_us"]["value"] < 1.0

    # the checked-in baseline at the repo root was NOT rewritten
    repo_json = os.path.join(_REPO, "BENCH_obs.json")
    if os.path.exists(repo_json):
        with open(repo_json) as fh:
            baseline = json.load(fh)
        assert baseline["_meta"]["bench"] == "bench_obs"
