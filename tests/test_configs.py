"""Config registry: published parameter counts, shape rules, input specs."""
import jax
import pytest

from repro.configs import (ARCHS, SHAPES, get_config, get_smoke, input_specs,
                           shape_applicable)
from repro.models import active_params, count_params

EXPECTED_B = {  # total params (1e9), +-15% of the published size
    "arctic-480b": 480, "qwen2-moe-a2.7b": 14.3, "mamba2-1.3b": 1.3,
    "command-r-plus-104b": 104, "stablelm-1.6b": 1.6, "smollm-360m": 0.36,
    "glm4-9b": 9.0, "llava-next-mistral-7b": 7.1, "musicgen-medium": 1.7,
    "jamba-v0.1-52b": 52,
}


@pytest.mark.parametrize("arch", list(ARCHS))
def test_param_counts_match_published(arch):
    n = count_params(get_config(arch)) / 1e9
    assert abs(n - EXPECTED_B[arch]) / EXPECTED_B[arch] < 0.15, n


def test_moe_active_params():
    cfg = get_config("qwen2-moe-a2.7b")
    a = active_params(cfg) / 1e9
    assert 1.5 < a < 3.5        # "A2.7B"


def test_long500k_rules():
    ok, _ = shape_applicable(get_config("mamba2-1.3b"), SHAPES["long_500k"])
    assert ok
    ok, _ = shape_applicable(get_config("jamba-v0.1-52b"),
                             SHAPES["long_500k"])
    assert ok
    ok, why = shape_applicable(get_config("glm4-9b"), SHAPES["long_500k"])
    assert not ok and "sub-quadratic" in why


@pytest.mark.parametrize("arch", list(ARCHS))
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_shapes(arch, shape):
    cfg = get_config(arch)
    sh = SHAPES[shape]
    specs = input_specs(cfg, sh)
    assert specs["tokens"].shape[0] == sh.global_batch
    if sh.kind == "train":
        assert specs["labels"].shape == specs["tokens"].shape
        total = specs["tokens"].shape[1] + \
            (specs["prefix_embeds"].shape[1] if "prefix_embeds" in specs
             else 0)
        assert total == sh.seq_len
    if sh.kind == "decode":
        assert specs["tokens"].shape == (sh.global_batch, 1)


@pytest.mark.parametrize("arch", list(ARCHS))
def test_smoke_configs_are_small(arch):
    assert count_params(get_smoke(arch)) < 5e6
