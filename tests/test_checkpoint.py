"""Checkpoint/restart: exact roundtrip, async saves, retention GC."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ck


def _tree(key):
    return {"a": jax.random.normal(key, (4, 3)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
            "scalar": jnp.float32(3.5)}


def test_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    ck.save(str(tmp_path), 7, tree)
    step, restored, meta = ck.restore(str(tmp_path), tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    tree = _tree(jax.random.PRNGKey(1))
    for s in (1, 2, 3, 4, 5):
        ck.save(str(tmp_path), s, tree, keep=3)
    assert ck.latest_step(str(tmp_path)) == 5
    dirs = sorted(os.listdir(tmp_path))
    assert len(dirs) == 3            # older checkpoints GC'd


def test_async_checkpointer(tmp_path):
    tree = _tree(jax.random.PRNGKey(2))
    acp = ck.AsyncCheckpointer(str(tmp_path))
    acp.save(10, tree)
    acp.wait()
    step, restored, _ = ck.restore(str(tmp_path), tree)
    assert step == 10


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ck.restore(str(tmp_path / "nope"), {"a": jnp.zeros(1)})


def test_restart_determinism(tmp_path):
    """Training resumed from a checkpoint matches uninterrupted training
    (the data pipeline re-derives batches from the step counter)."""
    from repro.data.pipeline import DataConfig, SyntheticLM
    data = SyntheticLM(DataConfig(vocab_size=64, seq_len=8, global_batch=4))
    b3_direct = data.global_batch(3)
    data2 = SyntheticLM(DataConfig(vocab_size=64, seq_len=8, global_batch=4))
    b3_resumed = data2.global_batch(3)
    np.testing.assert_array_equal(b3_direct["tokens"], b3_resumed["tokens"])
