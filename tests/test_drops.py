"""Drop-mask generators: rates, patterns, self-preservation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, strategies as st
except ImportError:
    from _hypothesis_fallback import given, strategies as st

from repro.core.drops import (bernoulli_mask, loss_fraction, make_mask,
                              straggler_mask, tail_mask)


@given(st.integers(0, 2**31 - 1), st.floats(0.01, 0.3))
def test_bernoulli_rate(seed, rate):
    m = bernoulli_mask(jax.random.PRNGKey(seed), 16, 4096, rate=rate,
                       packet_elems=64)
    observed = float(1 - jnp.mean(m))
    assert abs(observed - rate) < 0.08


def test_tail_mask_is_suffix():
    m = np.asarray(tail_mask(jax.random.PRNGKey(3), 8, 4096, rate=0.2,
                             packet_elems=64))
    for row in m:
        # once dropped, stays dropped (contiguous tail)
        drops = np.where(row == 0)[0]
        if len(drops):
            assert row[drops[0]:].max() == 0


def test_straggler_whole_rows():
    m = np.asarray(straggler_mask(jax.random.PRNGKey(4), 64, 128, rate=0.3))
    for row in m:
        assert row.min() == row.max()       # all-or-nothing per peer


def test_self_row_never_dropped():
    m = make_mask("straggler", jax.random.PRNGKey(0), 8, 100, rate=0.99,
                  self_index=jnp.asarray(3))
    assert float(jnp.min(m[3])) == 1.0


def test_zero_rate_is_ones():
    m = make_mask("tail", jax.random.PRNGKey(0), 4, 64, rate=0.0)
    assert float(jnp.min(m)) == 1.0


def test_loss_fraction():
    m = jnp.concatenate([jnp.ones((2, 50)), jnp.zeros((2, 50))], axis=1)
    assert float(loss_fraction(m)) == pytest.approx(0.5)


# ------------------------------------------------ properties (satellite):
# determinism in (key, receiver) and the n_elems % packet_elems != 0 tail
@given(st.integers(0, 2**31 - 1), st.integers(0, 7),
       st.sampled_from(["bernoulli", "tail", "straggler", "burst"]))
def test_mask_deterministic_in_key_and_receiver(seed, receiver, pattern):
    """The whole step is jit-compatible because masks are pure functions of
    (key, receiver): the pipeline folds the receiver id into the key, so
    the same (key, receiver) must give identical bytes on every call and a
    different receiver a different stream (for patterns that draw one)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), receiver)
    a = make_mask(pattern, key, 8, 1000, rate=0.2, packet_elems=64,
                  self_index=jnp.asarray(receiver))
    b = make_mask(pattern, key, 8, 1000, rate=0.2, packet_elems=64,
                  self_index=jnp.asarray(receiver))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    other = jax.random.fold_in(jax.random.PRNGKey(seed), receiver + 1)
    c = make_mask(pattern, other, 8, 1000, rate=0.2, packet_elems=64)
    assert c.shape == a.shape


@given(st.integers(0, 2**31 - 1),
       st.integers(1, 4 * 64).filter(lambda n: n % 64 != 0),
       st.sampled_from(["bernoulli", "tail", "straggler", "burst"]))
def test_mask_tail_edge_shape_and_values(seed, n_elems, pattern):
    """n_elems % packet_elems != 0: the packet-granular mask is generated
    for ceil(n/packet) packets and truncated — the shape must match exactly
    and every entry stay 0/1 (the expansion must not wrap or pad)."""
    m = np.asarray(make_mask(pattern, jax.random.PRNGKey(seed), 6, n_elems,
                             rate=0.25, packet_elems=64))
    assert m.shape == (6, n_elems)
    assert set(np.unique(m)) <= {0.0, 1.0}


@given(st.integers(0, 2**31 - 1),
       st.integers(65, 8 * 64).filter(lambda n: n % 64 != 0))
def test_tail_mask_suffix_property_at_tail_edge(seed, n_elems):
    """The tail pattern's defining invariant — once dropped, stays dropped
    (a timeout cuts a contiguous suffix) — must hold when the last packet
    is partial."""
    m = np.asarray(tail_mask(jax.random.PRNGKey(seed), 8, n_elems, rate=0.2,
                             packet_elems=64))
    for row in m:
        drops = np.where(row == 0)[0]
        if len(drops):
            assert row[drops[0]:].max() == 0


@given(st.integers(0, 2**31 - 1),
       st.integers(1, 4 * 64).filter(lambda n: n % 64 != 0))
def test_self_row_preserved_at_tail_edge(seed, n_elems):
    m = make_mask("bernoulli", jax.random.PRNGKey(seed), 8, n_elems,
                  rate=0.9, packet_elems=64, self_index=jnp.asarray(5))
    assert float(jnp.min(m[5])) == 1.0


# ----------------------------------------------- burst (Gilbert–Elliott)
def _burst_runs(rate: float, keys: int = 30, n: int = 16,
                n_packets: int = 128):
    """Packet-granular burst masks over many keys -> (loss_frac, runs)."""
    from repro.core.drops import burst_mask
    lost = total = 0
    runs = []
    for s in range(keys):
        m = np.asarray(burst_mask(jax.random.PRNGKey(s), n, n_packets,
                                  rate=rate, packet_elems=1))
        lost += int((1 - m).sum())
        total += m.size
        for row in 1 - m.astype(int):
            # zero-run lengths: edges of the padded loss indicator
            edges = np.flatnonzero(np.diff(np.concatenate(
                [[0], row, [0]])))
            runs.extend((edges[1::2] - edges[::2]).tolist())
    return lost / total, runs


def test_burst_stationary_loss_tracks_rate():
    """The Gilbert–Elliott chain starts from its stationary distribution,
    so the long-run loss fraction equals the scripted rate (clustered into
    bursts, hence the loose tolerance)."""
    observed, _ = _burst_runs(rate=0.1)
    assert abs(observed - 0.1) < 0.03


def test_burst_run_lengths_near_mean_burst():
    """Bad-state sojourns are geometric with mean BURST_MEAN_PKTS — the
    property that distinguishes burst from bernoulli at equal rate (row
    truncation biases the sample mean down slightly)."""
    from repro.core.drops import BURST_MEAN_PKTS
    _, runs = _burst_runs(rate=0.1)
    assert len(runs) > 50
    mean_run = float(np.mean(runs))
    assert BURST_MEAN_PKTS * 0.6 < mean_run < BURST_MEAN_PKTS * 1.4
    # genuinely bursty: multi-packet runs dominate over singletons
    assert float(np.mean(np.asarray(runs) > 1)) > 0.5


def test_burst_clusters_vs_bernoulli_at_equal_rate():
    """At the same stationary rate, bernoulli's mean run is ~1/(1-rate)
    (≈1.1) while burst's is BURST_MEAN_PKTS — the whole point of the
    pattern (DESIGN §8: bursts are what zero-fill handles worst)."""
    m = np.asarray(bernoulli_mask(jax.random.PRNGKey(0), 16, 2048, rate=0.1,
                                  packet_elems=1))
    bruns = []
    for row in 1 - m.astype(int):
        edges = np.flatnonzero(np.diff(np.concatenate([[0], row, [0]])))
        bruns.extend((edges[1::2] - edges[::2]).tolist())
    _, runs = _burst_runs(rate=0.1, keys=10)
    assert np.mean(runs) > 3 * np.mean(bruns)
