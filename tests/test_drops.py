"""Drop-mask generators: rates, patterns, self-preservation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, strategies as st
except ImportError:
    from _hypothesis_fallback import given, strategies as st

from repro.core.drops import (bernoulli_mask, loss_fraction, make_mask,
                              straggler_mask, tail_mask)


@given(st.integers(0, 2**31 - 1), st.floats(0.01, 0.3))
def test_bernoulli_rate(seed, rate):
    m = bernoulli_mask(jax.random.PRNGKey(seed), 16, 4096, rate=rate,
                       packet_elems=64)
    observed = float(1 - jnp.mean(m))
    assert abs(observed - rate) < 0.08


def test_tail_mask_is_suffix():
    m = np.asarray(tail_mask(jax.random.PRNGKey(3), 8, 4096, rate=0.2,
                             packet_elems=64))
    for row in m:
        # once dropped, stays dropped (contiguous tail)
        drops = np.where(row == 0)[0]
        if len(drops):
            assert row[drops[0]:].max() == 0


def test_straggler_whole_rows():
    m = np.asarray(straggler_mask(jax.random.PRNGKey(4), 64, 128, rate=0.3))
    for row in m:
        assert row.min() == row.max()       # all-or-nothing per peer


def test_self_row_never_dropped():
    m = make_mask("straggler", jax.random.PRNGKey(0), 8, 100, rate=0.99,
                  self_index=jnp.asarray(3))
    assert float(jnp.min(m[3])) == 1.0


def test_zero_rate_is_ones():
    m = make_mask("tail", jax.random.PRNGKey(0), 4, 64, rate=0.0)
    assert float(jnp.min(m)) == 1.0


def test_loss_fraction():
    m = jnp.concatenate([jnp.ones((2, 50)), jnp.zeros((2, 50))], axis=1)
    assert float(loss_fraction(m)) == pytest.approx(0.5)
