"""Composable collective-pipeline API: registry semantics, spec validation,
codec/topology/transport protocol behavior, and the AdaptiveTransport
control loop. Multi-device oracle equivalence lives in
tests/test_pipeline_parity.py."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.core import (OptiReduceConfig, SyncContext, strategies,
                        sync_bucket)
from repro.core import pipeline as pl
from repro.core import tar as tar_lib
from repro.core.allreduce import rs_spec

SEED_STRATEGIES = ("psum", "gloo_ring", "nccl_tree", "bcube", "tar_tcp",
                   "tar_rounds", "optireduce", "optireduce_2d",
                   "optireduce_q")


def test_registry_covers_every_seed_strategy():
    names = strategies()
    for s in SEED_STRATEGIES:
        assert s in names, s
    # and the layering opened new cross-product compositions
    for s in ("optireduce_rounds", "tar_rounds_q", "ring_ht"):
        assert s in names, s


def test_resolve_unknown_strategy_raises_with_names():
    with pytest.raises(ValueError, match="unknown strategy"):
        pl.resolve_spec(OptiReduceConfig(strategy="nope"))


def test_stageless_topology_gets_descriptive_pipelined_error():
    """A Topology overriding only ``all_reduce`` (the PR-2 protocol) still
    works under scan/vmap, but mode='pipelined' needs the stage callables —
    and must say so instead of dying with a bare NotImplementedError deep
    in the schedule."""
    from repro.core.allreduce import sync_pytree

    class AllReduceOnly(pl.Topology):
        def all_reduce(self, bucket, transport, codec, ctx):
            return jax.lax.pmean(bucket, ctx.data_axes())

    spec = pl.CollectiveSpec(AllReduceOnly(), pl.Reliable(), pl.Identity())
    mesh = make_mesh((1,), ("data",))
    tree = {"g": jnp.ones((2048,))}

    def body(t, mode):
        ctx = SyncContext(cfg=OptiReduceConfig(), key=jax.random.PRNGKey(0))
        return sync_pytree(t, ctx, bucket_elems=1024, mode=mode, spec=spec)

    f = shard_map(lambda t: body(t, "scan"), mesh=mesh,
                  in_specs=({"g": P()},), out_specs={"g": P()},
                  check_vma=False)
    np.testing.assert_array_equal(np.asarray(f(tree)["g"]),
                                  np.asarray(tree["g"]))
    with pytest.raises(NotImplementedError, match="pipelined.*AllReduceOnly"):
        shard_map(lambda t: body(t, "pipelined"), mesh=mesh,
                  in_specs=({"g": P()},), out_specs={"g": P()},
                  check_vma=False)(tree)


def test_register_strategy_instance_and_decorator():
    spec = pl.CollectiveSpec(pl.RingTopology("tree"), pl.Reliable(),
                             pl.Hadamard())
    try:
        pl.register_strategy("_tmp_instance", spec)
        assert pl.resolve_spec(
            OptiReduceConfig(strategy="_tmp_instance")) is spec

        @pl.register_strategy("_tmp_factory")
        def _factory(cfg):
            return pl.CollectiveSpec(
                pl.TarTopology(), pl.Lossy(),
                pl.HTQuant() if cfg.quant_bits < 8 else pl.Identity())

        got = pl.resolve_spec(OptiReduceConfig(strategy="_tmp_factory",
                                               quant_bits=4))
        assert isinstance(got.codec, pl.HTQuant)
        got = pl.resolve_spec(OptiReduceConfig(strategy="_tmp_factory"))
        assert isinstance(got.codec, pl.Identity)
    finally:
        pl._REGISTRY.pop("_tmp_instance", None)
        pl._REGISTRY.pop("_tmp_factory", None)


def test_invalid_compositions_rejected_at_spec_time():
    # ring reduces partial sums in flight: the UBT drop model needs TAR
    with pytest.raises(ValueError, match="TarTopology"):
        pl.CollectiveSpec(pl.RingTopology("ring"), pl.Lossy(), pl.Identity())
    # a non-linear codec cannot commute with ring's internal reduction
    with pytest.raises(ValueError, match="commute"):
        pl.CollectiveSpec(pl.RingTopology("ring"), pl.Reliable(),
                          pl.HTQuant())
    # psum is XLA-native: no codec, no drops
    with pytest.raises(ValueError, match="psum"):
        pl.CollectiveSpec(pl.PsumTopology(), pl.Lossy(), pl.Identity())
    with pytest.raises(ValueError, match="unknown TAR schedule"):
        pl.TarTopology(schedule="carrier_pigeon")
    with pytest.raises(ValueError, match="unknown ring topology"):
        pl.RingTopology("mobius")


@pytest.mark.parametrize("strategy", SEED_STRATEGIES + (
    "optireduce_rounds", "tar_rounds_q", "ring_ht"))
def test_every_registered_spec_is_identity_at_dp1(strategy):
    """dp=1 degenerates every composition to (approximately) the identity —
    a coherence check of the whole Topology x Transport x Codec dispatch."""
    mesh = make_mesh((1,), ("data",))
    cfg = OptiReduceConfig(strategy=strategy, drop_rate=0.0,
                           hadamard_block=256)

    def body(x):
        return sync_bucket(x, SyncContext(cfg=cfg, key=jax.random.PRNGKey(0)))

    f = jax.jit(shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                          check_vma=False))
    x = jax.random.normal(jax.random.PRNGKey(1), (2048,))
    out = np.asarray(f(x))
    tol = 0.2 if "q" in strategy else 1e-4     # quantization error vs fp
    assert np.max(np.abs(out - np.asarray(x))) < tol


def test_masked_mean_is_public_and_matches_ref():
    from repro.kernels.masked_sum import masked_mean_ref
    key = jax.random.PRNGKey(0)
    received = jax.random.normal(key, (4, 512))
    mask = (jax.random.uniform(jax.random.fold_in(key, 1),
                               (4, 512)) > 0.2).astype(jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(tar_lib.masked_mean(received, None)),
        np.asarray(jnp.mean(received, axis=0)))
    np.testing.assert_array_equal(
        np.asarray(tar_lib.masked_mean(received, mask)),
        np.asarray(masked_mean_ref(received, mask)))
    assert not hasattr(tar_lib, "_reduce")     # the private form is gone


def test_rounds_split_composes_to_allreduce():
    """tar_exchange_rounds + mean + tar_broadcast_rounds == the one-shot
    tar_allreduce_rounds wrapper (single device: schedule degenerates)."""
    mesh = make_mesh((1,), ("data",))
    x = jax.random.normal(jax.random.PRNGKey(0), (1024,))

    def a(v):
        return tar_lib.tar_allreduce_rounds(v, "data", incast=2)

    def b(v):
        rec = tar_lib.tar_exchange_rounds(v.reshape(1, -1), "data", incast=2)
        return tar_lib.tar_broadcast_rounds(jnp.mean(rec, 0), "data",
                                            incast=2)

    fa = jax.jit(shard_map(a, mesh=mesh, in_specs=P(), out_specs=P(),
                           check_vma=False))
    fb = jax.jit(shard_map(b, mesh=mesh, in_specs=P(), out_specs=P(),
                           check_vma=False))
    np.testing.assert_array_equal(np.asarray(fa(x)), np.asarray(fb(x)))


def test_rs_spec_codec_selection():
    cfg = OptiReduceConfig(drop_rate=0.0, rs_wire_bits=0)
    assert isinstance(rs_spec(cfg).codec, pl.Identity)
    cfg = OptiReduceConfig(drop_rate=0.05, use_hadamard=True)
    assert isinstance(rs_spec(cfg).codec, pl.Hadamard)
    assert isinstance(rs_spec(cfg, with_drops=False).codec, pl.Identity)
    cfg = OptiReduceConfig(drop_rate=0.0, rs_wire_bits=8)
    codec = rs_spec(cfg).codec
    assert isinstance(codec, pl.HTQuant)
    assert codec.bits == 8 and codec.noise_salt == 9
    assert isinstance(rs_spec(cfg).transport, pl.Lossy)
    assert isinstance(rs_spec(cfg, with_drops=False).transport, pl.Reliable)
    assert not isinstance(rs_spec(cfg, with_drops=False).transport, pl.Lossy)


def test_adaptive_transport_controllers():
    """§3.2 plumbing: loss-free rounds grow the advertised incast, loss
    halves it, and Hadamard activates above the 2% threshold (fn. 6)."""
    at = pl.AdaptiveTransport.create(n_nodes=8)
    assert at.incast() == 1 and not at.use_hadamard
    for _ in range(4):                       # clean rounds: I ramps
        at.observe(0.0, stage_time=0.1)
    assert at.incast() == 5
    assert not at.use_hadamard
    changed = at.observe(0.05)               # 5% loss: halve I, HT on
    assert changed
    assert at.incast() == 2
    assert at.use_hadamard
    cfg = OptiReduceConfig(strategy="optireduce_rounds", use_hadamard=False)
    applied = at.apply(cfg)
    assert applied.use_hadamard and applied.incast == 2
    assert at.observe(0.05) and at.incast() == 1
    assert not at.observe(0.05)              # I floors at 1, HT stays: no-op
    # and it is still a Lossy transport (drop masks + stats in the graph)
    assert isinstance(at, pl.Lossy)


def test_sync_pytree_accepts_explicit_spec():
    """An unregistered ad-hoc spec can drive sync_pytree directly."""
    from repro.core import sync_pytree
    mesh = make_mesh((1,), ("data",))
    spec = pl.CollectiveSpec(pl.TarTopology(), pl.Reliable(), pl.Hadamard())
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (2048,))}
    cfg = OptiReduceConfig(strategy="does_not_matter_with_spec",
                           hadamard_block=256)

    def body(t):
        ctx = SyncContext(cfg=cfg, key=jax.random.PRNGKey(5))
        return sync_pytree(t, ctx, bucket_elems=1024, spec=spec)

    f = jax.jit(shard_map(body, mesh=mesh, in_specs=({"w": P()},),
                          out_specs={"w": P()}, check_vma=False))
    out = f(tree)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(tree["w"]), atol=1e-4)
