"""Mamba-2 SSD: chunked train form == sequential recurrence == split runs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import ssd_chunked, ssd_decode_step


def _inputs(key, B=2, S=32, H=4, P=8, N=16, G=1):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a_log = jnp.log(jax.random.uniform(ks[2], (H,), minval=1.0, maxval=4.0))
    b = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    c = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    return x, dt, a_log, b, c


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunked_matches_sequential(chunk):
    x, dt, a_log, b, c = _inputs(jax.random.PRNGKey(0))
    y_chunk, final = ssd_chunked(x, dt, a_log, b, c, chunk=chunk)
    state = jnp.zeros((2, 4, 8, 16), jnp.float32)
    ys = []
    for t in range(32):
        y, state = ssd_decode_step(x[:, t:t+1], dt[:, t:t+1], a_log,
                                   b[:, t:t+1], c[:, t:t+1], state)
        ys.append(y)
    y_seq = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(final), np.asarray(state),
                               atol=1e-4)


def test_state_carry_split_runs():
    x, dt, a_log, b, c = _inputs(jax.random.PRNGKey(1))
    y_full, _ = ssd_chunked(x, dt, a_log, b, c, chunk=8)
    y1, st = ssd_chunked(x[:, :16], dt[:, :16], a_log, b[:, :16], c[:, :16],
                         chunk=8)
    y2, _ = ssd_chunked(x[:, 16:], dt[:, 16:], a_log, b[:, 16:], c[:, 16:],
                        chunk=8, init_state=st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-5)


def test_decay_stability():
    """Large dt*A must not produce NaN/inf (exp of negative only)."""
    x, dt, a_log, b, c = _inputs(jax.random.PRNGKey(2))
    y, final = ssd_chunked(x, dt * 100, a_log, b, c, chunk=8)
    assert not bool(jnp.isnan(y).any())
    assert not bool(jnp.isinf(final).any())
