"""THC uniform stochastic quantization: kernel parity, error bound, and the
unbiasedness/homomorphic properties THC aggregation needs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, strategies as st
except ImportError:
    from _hypothesis_fallback import given, strategies as st

from repro.kernels.quant import (grid_quant, grid_quant_ref, uniform_dequant,
                                 uniform_quant, uniform_quant_ref)


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("shape", [(4, 256), (17, 1000)])
def test_kernel_matches_oracle(bits, shape):
    key = jax.random.PRNGKey(bits)
    x = jax.random.normal(key, shape)
    noise = jax.random.uniform(jax.random.fold_in(key, 1), shape)
    lohi = jnp.array([float(x.min()) - 1e-3, float(x.max()) + 1e-3])
    a = uniform_quant(x, noise, lohi, bits=bits, use_kernel=True)
    b = uniform_quant_ref(x, noise, lohi[0], lohi[1], bits=bits)
    assert int(jnp.max(jnp.abs(a.astype(jnp.int32) -
                               b.astype(jnp.int32)))) == 0


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("shape", [(4, 256), (37, 1000), (131, 256)])
def test_grid_quant_kernel_matches_ref(bits, shape):
    """Per-row-grid quantizer (TAR stage-2 shard re-encode): kernel ==
    jnp oracle bit-exactly, including the padded-rows path."""
    key = jax.random.PRNGKey(bits + shape[0])
    x = jax.random.normal(key, shape)
    noise = jax.random.uniform(jax.random.fold_in(key, 1), shape)
    amax = jnp.maximum(jnp.max(jnp.abs(x), axis=1), 1e-12)
    levels = (1 << bits) - 1
    lo, step = -amax, 2.0 * amax / levels
    a = grid_quant(x, noise, lo, step, bits=bits, use_kernel=True)
    b = grid_quant_ref(x, noise, lo, step, bits=bits)
    assert a.dtype == jnp.uint8
    assert int(jnp.max(jnp.abs(a.astype(jnp.int32) -
                               b.astype(jnp.int32)))) == 0


def test_grid_quant_matches_scalar_quant_on_uniform_grid():
    """With every row sharing one grid, grid_quant degenerates to the
    scalar-grid uniform_quant."""
    key = jax.random.PRNGKey(9)
    x = jax.random.normal(key, (16, 512))
    noise = jax.random.uniform(jax.random.fold_in(key, 1), x.shape)
    lohi = jnp.array([-4.0, 4.0])
    levels = 255
    lo = jnp.full((16,), -4.0)
    step = jnp.full((16,), 8.0 / levels)
    a = grid_quant(x, noise, lo, step, bits=8, use_kernel=True)
    b = uniform_quant(x, noise, lohi, bits=8, use_kernel=True)
    assert int(jnp.max(jnp.abs(a.astype(jnp.int32) -
                               b.astype(jnp.int32)))) == 0


@pytest.mark.parametrize("bits", [4, 8])
def test_dequant_error_bound(bits):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, 512))
    noise = jax.random.uniform(jax.random.fold_in(key, 1), x.shape)
    lohi = jnp.array([float(x.min()) - 1e-3, float(x.max()) + 1e-3])
    codes = uniform_quant(x, noise, lohi, bits=bits)
    step = float(lohi[1] - lohi[0]) / ((1 << bits) - 1)
    err = float(jnp.max(jnp.abs(uniform_dequant(codes, lohi, bits=bits) - x)))
    assert err <= step + 1e-5


@given(st.integers(0, 2**31 - 1))
def test_stochastic_rounding_unbiased(seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (1, 64)) * 0.5
    lohi = jnp.array([-3.0, 3.0])
    trials = 256
    noise = jax.random.uniform(jax.random.fold_in(key, 7), (trials, 64))
    codes = jax.vmap(lambda n: uniform_quant(x[0:1], n[None], lohi,
                                             bits=4))(noise)
    deq = uniform_dequant(codes.astype(jnp.float32), lohi, bits=4)
    mean = jnp.mean(deq, axis=0)[0]
    step = 6.0 / 15
    assert float(jnp.max(jnp.abs(mean - x[0]))) < step / 2


def test_homomorphic_sum():
    """Sum of codes dequantizes to (approximately) the sum of values when
    quantized on a shared grid — THC's aggregation property."""
    key = jax.random.PRNGKey(2)
    n = 8
    xs = jax.random.normal(key, (n, 512))
    lohi = jnp.array([-6.0, 6.0])
    noise = jax.random.uniform(jax.random.fold_in(key, 1), xs.shape)
    codes = jax.vmap(lambda x, u: uniform_quant(x[None], u[None], lohi,
                                                bits=8))(xs, noise)
    code_sum = jnp.sum(codes.astype(jnp.int32), axis=0)
    approx = uniform_dequant(code_sum, lohi, bits=8, nsum=n)
    step = 12.0 / 255
    err = float(jnp.max(jnp.abs(approx - jnp.sum(xs, 0))))
    assert err <= n * step
