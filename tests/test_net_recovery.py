"""StaleFill / error-feedback recovery over the real wire (DESIGN §8 on
the §9 transport).

The core recovery suite proves the mechanisms against *synthetic* masks (a
pure function of the step key); here the masks are whatever the receivers
actually observed on the wire — injected loss AND reordering — and the
same exactness laws must hold against ``HostPeer.last_mask1``:

  * StaleFill conservation:   mean_i(x_i) == out + mean_i((1-m_i)(x_i - stale))
  * EF ledger (telescoped):   sum_t mean_i(x_i^t) == sum_t out^t + mean_i(r_i^T)

Loss is injected on stage-1 DATA only (stage 2 stays lossless) so every
rank decodes the identical aggregate and the conservation ledger closes
exactly.  UDP cases add ``scramble_seed`` reordering on top of the drops —
reassembly must be order-free for the laws to survive (auto-skip when the
sandbox forbids sockets).
"""
import jax
import numpy as np
import pytest

from repro.core.allreduce import OptiReduceConfig
from repro.core.hadamard import ht_decode, ht_encode
from repro.core.pipeline import resolve_spec
from repro.core.recovery import StaleFill
from repro.net import HostRing, bernoulli_drops, udp_available
from repro.net.wire import KIND_DATA1

pytestmark = pytest.mark.net

needs_udp = pytest.mark.skipif(not udp_available(),
                               reason="sandbox forbids UDP sockets")

N = 4
ELEMS = 4096          # = N * 1024: no TAR padding, shard spans align


def _cfg(**kw):
    base = dict(strategy="optireduce", use_hadamard=False, drop_rate=0.0,
                packet_elems=256, recovery="stale")
    base.update(kw)
    return OptiReduceConfig(**base)


def _data1_drops(rate, seed):
    """Bernoulli loss on stage-1 DATA only — CTRL and stage-2 stay clean,
    so all ranks decode identical bytes and the ledger closes exactly."""
    base = bernoulli_drops(rate, seed=seed)

    def drop(src, dst, hdr):
        return hdr.kind == KIND_DATA1 and base(src, dst, hdr)
    return drop


def _data(step, elems=ELEMS, seed=0):
    return np.random.default_rng(seed + step).standard_normal(
        (N, elems)).astype(np.float32)


def _key(step, seed=0):
    return jax.random.fold_in(jax.random.PRNGKey(seed), step)


def _element_mask(ring, elems):
    """(N_sender, elems) element-wise stage-1 arrival matrix: column span
    ``[p*s, (p+1)*s)`` of sender ``i``'s row is receiver p's observed
    ``last_mask1[i]`` (receiver p owns shard p)."""
    s = elems // N
    cols = [np.asarray(ring.peers[p].last_mask1) for p in range(N)]
    assert all(c.shape == (N, s) for c in cols)
    return np.concatenate(cols, axis=1)


def _assert_stage2_clean(ring):
    for p in range(N):
        m2 = ring.peers[p].last_mask2
        assert m2 is None or np.all(np.asarray(m2) == 1.0)


def _run_stalefill(ring, steps, elems=ELEMS):
    """Thread ``stale`` = previous step's decoded bucket (step 0: zeros —
    exactly zero-fill) and record (data, stale, out, element_mask)."""
    stale = np.zeros(elems, np.float32)
    recs = []
    for step in range(steps):
        data = _data(step, elems)
        out, _ = ring.allreduce(data, _key(step), step=step, bucket=0,
                                stale=stale)
        _assert_stage2_clean(ring)
        for p in range(1, N):           # lossless stage 2: one truth
            np.testing.assert_array_equal(out[0], out[p])
        recs.append((data, stale, np.asarray(out[0]),
                     _element_mask(ring, elems)))
        stale = np.asarray(out[0])
    return recs


# ------------------------------------------------- conservation (identity)
def test_stalefill_conserves_mass_inproc():
    """Identity codec: wire == value space, so the law is elementwise —
    what the fill did NOT recover is exactly the masked gap to the stale
    prediction, reconstructed from the receivers' observed masks."""
    ring = HostRing(N, _cfg(), backend="inproc",
                    drop_fn=_data1_drops(0.15, seed=7))
    recs = _run_stalefill(ring, steps=3)
    saw_loss = False
    for data, stale, out, mask in recs:
        saw_loss |= bool(np.any(mask == 0.0))
        gap = ((1.0 - mask) * (data - stale[None, :])).mean(axis=0)
        np.testing.assert_allclose(data.mean(axis=0), out + gap,
                                   rtol=1e-5, atol=1e-5)
    assert saw_loss, "drop injection never fired — the law was vacuous"


def test_stalefill_differs_from_compensated_mean_once_cache_is_warm():
    """Same wire, recovery on vs off: step 0 (zero cache) the fill IS
    zero-fill-with-plain-mean, but once the cache holds step 0's decoded
    bucket the prediction pulls lost spans toward it — outputs diverge."""
    drop = _data1_drops(0.15, seed=7)
    ring_fill = HostRing(N, _cfg(), backend="inproc", drop_fn=drop)
    ring_none = HostRing(N, _cfg(recovery="none"), backend="inproc",
                         drop_fn=drop)
    recs = _run_stalefill(ring_fill, steps=2)
    outs_none = []
    for step in range(2):
        out, _ = ring_none.allreduce(_data(step), _key(step), step=step,
                                     bucket=0)
        outs_none.append(np.asarray(out[0]))
    # warm-cache step must differ (the prediction carries real mass)
    assert not np.allclose(recs[1][2], outs_none[1], atol=1e-6)


def test_stalefill_hadamard_conserves_mass_in_wire_space():
    """Hadamard codec: masks live in *rotated* space, so the conservation
    law decodes the masked wire gap — exact only because HT is linear and
    the stale cache is re-encoded under the same per-step key."""
    cfg = _cfg(use_hadamard=True, hadamard_block=256)
    ring = HostRing(N, cfg, backend="inproc",
                    drop_fn=_data1_drops(0.15, seed=11))
    recs = _run_stalefill(ring, steps=2)
    for step, (data, stale, out, mask) in enumerate(recs):
        key = _key(step)
        w = np.stack([np.asarray(ht_encode(data[i], key, block=256))
                      for i in range(N)])
        w_stale = np.asarray(ht_encode(stale, key, block=256))
        gap_wire = ((1.0 - mask) * (w - w_stale[None, :])).mean(axis=0)
        gap = np.asarray(ht_decode(gap_wire, key, block=256))
        np.testing.assert_allclose(data.mean(axis=0), out + gap,
                                   rtol=1e-4, atol=1e-4)


# ------------------------------------------------------- EF exactly-once
def test_ef_ledger_closes_exactly_once_inproc():
    """Error feedback over the real wire: each rank carries the residual
    its receivers' observed masks say went undelivered (minus what the
    stale fill applied in its stead) into the next step's contribution.
    Telescoping the per-step law gives the exactly-once ledger:

        sum_t mean_i(x_i^t) == sum_t out^t + mean_i(r_i^T)

    — every unit of gradient mass is applied once: now, or (discounted by
    the fill) later, or it is still on the books in the final residual.
    """
    steps = 4
    ring = HostRing(N, _cfg(recovery="ef"), backend="inproc",
                    drop_fn=_data1_drops(0.2, seed=5))
    stale = np.zeros(ELEMS, np.float32)
    resid = np.zeros((N, ELEMS), np.float32)
    sum_true = np.zeros(ELEMS, np.float64)
    sum_out = np.zeros(ELEMS, np.float64)
    resid_was_nonzero = False
    for step in range(steps):
        data = _data(step)
        contrib = data + resid
        out, _ = ring.allreduce(contrib, _key(step), step=step, bucket=0,
                                stale=stale)
        _assert_stage2_clean(ring)
        out0 = np.asarray(out[0])
        mask = _element_mask(ring, ELEMS)
        # sender-side residual from the *observed* masks: what I owed,
        # minus the prediction the receivers already applied for me
        resid = ((1.0 - mask) * (contrib - stale[None, :])).astype(
            np.float32)
        resid_was_nonzero |= bool(np.any(resid != 0.0))
        sum_true += data.mean(axis=0)
        sum_out += out0
        stale = out0
    np.testing.assert_allclose(sum_true, sum_out + resid.mean(axis=0),
                               rtol=1e-4, atol=1e-4)
    assert resid_was_nonzero, "no mass was ever deferred — vacuous ledger"


# ------------------------------------------------------------ UDP + reorder
@needs_udp
def test_stalefill_conserves_mass_over_udp_with_reordering():
    """The same conservation law over real datagrams with loss AND
    scrambled send order — reassembly must be order-free for the observed
    masks to still account for exactly the missing mass.  The generous
    deadline keeps wall-clock expiry out of the masks (scripted loss
    only)."""
    elems = 2048
    ring = HostRing(N, _cfg(packet_elems=128), backend="udp",
                    drop_fn=_data1_drops(0.15, seed=13),
                    scramble_seed=11, default_deadline=2.0)
    recs = _run_stalefill(ring, steps=2, elems=elems)
    saw_loss = False
    for data, stale, out, mask in recs:
        saw_loss |= bool(np.any(mask == 0.0))
        gap = ((1.0 - mask) * (data - stale[None, :])).mean(axis=0)
        np.testing.assert_allclose(data.mean(axis=0), out + gap,
                                   rtol=1e-5, atol=1e-5)
    assert saw_loss


@needs_udp
def test_udp_reordering_is_invariant_under_loss():
    """Drops are header-pure and reassembly is positional: two runs
    differing only in the scramble permutation (and one with none) must
    produce bitwise identical results."""
    elems, step = 2048, 0
    outs = []
    for scramble in (None, 1, 97):
        ring = HostRing(N, _cfg(packet_elems=128), backend="udp",
                        drop_fn=_data1_drops(0.15, seed=13),
                        scramble_seed=scramble, default_deadline=2.0)
        out, _ = ring.allreduce(_data(step, elems), _key(step), step=step,
                                bucket=0, stale=np.zeros(elems, np.float32))
        outs.append(np.asarray(out))
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


# ---------------------------------------------------------- composability
def test_recovery_composability_guards():
    """The registry rejects the combinations the math cannot serve, and
    ``recovery="none"`` resolves to the unwrapped (seed) codec."""
    with pytest.raises(ValueError, match="linear"):
        resolve_spec(OptiReduceConfig(strategy="optireduce_q",
                                      recovery="stale"))
    with pytest.raises(ValueError, match="active_peers|degraded"):
        resolve_spec(OptiReduceConfig(strategy="optireduce", recovery="ef",
                                      active_peers=(0, 1, 2)))
    assert isinstance(resolve_spec(_cfg()).codec, StaleFill)
    assert not isinstance(resolve_spec(_cfg(recovery="none")).codec,
                          StaleFill)


def test_stale_none_collapses_to_compensated_mean():
    """With the wrapper armed but no cache offered (``stale=None``) the
    reduce must fall back bitwise to the compensated masked mean — the
    collapse-when-disabled property, on the wire path."""
    drop = _data1_drops(0.15, seed=7)
    out_fill, _ = HostRing(N, _cfg(), backend="inproc",
                           drop_fn=drop).allreduce(
        _data(0), _key(0), step=0, bucket=0, stale=None)
    out_none, _ = HostRing(N, _cfg(recovery="none"), backend="inproc",
                           drop_fn=drop).allreduce(
        _data(0), _key(0), step=0, bucket=0)
    np.testing.assert_array_equal(np.asarray(out_fill),
                                  np.asarray(out_none))
