"""CI smoke for the wire-transport bench: ``python -m benchmarks.run
--only bench_transport`` in quick mode must keep producing the schema the
PR-over-PR trajectory diffs consume — inproc/udp round-latency medians with
``_iqr_ms`` dispersion siblings, the scripted-loss fidelity sweep, and the
reassembly-overhead rows — so the harness cannot rot silently between PRs.

Writes to a tmpdir via ``REPRO_BENCH_DIR`` so a test run never rewrites the
checked-in BENCH_transport.json baseline.
"""
import json
import os
import subprocess
import sys

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
@pytest.mark.net
def test_bench_transport_quick_schema(tmp_path):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    src = os.path.join(_REPO, "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [_REPO, src, env.get("PYTHONPATH", "")])
    env["REPRO_BENCH_DIR"] = str(tmp_path)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "bench_transport"],
        env=env, cwd=_REPO, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "FAILED" not in proc.stdout, proc.stdout

    path = tmp_path / "BENCH_transport.json"
    assert path.exists(), "run.py did not honor REPRO_BENCH_DIR"
    payload = json.loads(path.read_text())
    assert payload["_meta"] == {"mode": "quick", "bench": "bench_transport"}

    keys = set(payload) - {"_meta"}
    for key in ("transport/inproc_64KB_roundtrip_median_ms",
                "transport/udp_64KB_roundtrip_median_ms",
                "transport/loss_sweep_rate_0_observed",
                "transport/loss_sweep_rate_0.01_observed",
                "transport/loss_sweep_rate_0.05_observed",
                "transport/reassembly_64KB_median_ms",
                "transport/inproc_scale_16p_median_ms",
                "transport/inproc_scale_32p_median_ms",
                "transport/inproc_scale_64p_median_ms",
                "transport/udp_scale_16p_median_ms",
                "transport/udp_scale_32p_median_ms"):
        assert key in keys, key
    # every median row carries its dispersion sibling (run.py schema)
    for key in keys:
        if key.endswith("_median_ms"):
            assert key[:-len("_median_ms")] + "_iqr_ms" in keys, key
    for key in keys:
        assert isinstance(payload[key]["value"], (int, float)), key

    # loss fidelity: the observed loss_fraction is monotone in the
    # scripted rate and zero at rate 0
    l0 = payload["transport/loss_sweep_rate_0_observed"]["value"]
    l1 = payload["transport/loss_sweep_rate_0.01_observed"]["value"]
    l5 = payload["transport/loss_sweep_rate_0.05_observed"]["value"]
    assert l0 == 0.0
    assert 0.0 < l1 < l5

    # the checked-in baseline at the repo root was NOT rewritten
    repo_json = os.path.join(_REPO, "BENCH_transport.json")
    if os.path.exists(repo_json):
        with open(repo_json) as fh:
            baseline = json.load(fh)
        assert baseline["_meta"]["bench"] == "bench_transport"
