"""Elastic re-sharding: shards -> full -> shards' roundtrips across N."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import init_params
from repro.train.elastic import gather_shards, reshard


def test_reshard_roundtrip():
    cfg = get_smoke("gpt2-paper")
    params = init_params(jax.random.PRNGKey(0), cfg)
    shards4 = reshard(params, cfg, 4)
    assert len(shards4) == 4
    # scale down to 2 workers via reassembly
    full = gather_shards(shards4, cfg)
    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    shards2 = reshard(full, cfg, 2)
    assert len(shards2) == 2
    full2 = gather_shards(shards2, cfg)
    for a, b in zip(jax.tree.leaves(full2), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shards_partition_fsdp_dims():
    cfg = get_smoke("gpt2-paper")
    params = init_params(jax.random.PRNGKey(1), cfg)
    shards = reshard(params, cfg, 2)
    w_full = np.asarray(params["stages"][0]["w_gate"])
    w0 = np.asarray(shards[0]["stages"][0]["w_gate"])
    w1 = np.asarray(shards[1]["stages"][0]["w_gate"])
    # w_gate fsdp dim is 1 (D) in the (L, D, F) layout
    assert w0.shape[1] * 2 == w_full.shape[1]
    np.testing.assert_array_equal(np.concatenate([w0, w1], axis=1), w_full)
