"""FWHT kernel: Pallas (interpret) vs butterfly oracle across shapes/dtypes,
plus the algebraic properties the OptiReduce pipeline relies on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, strategies as st
except ImportError:
    from _hypothesis_fallback import given, strategies as st

from repro.kernels.fwht import (fwht, fwht_mxu_ref, fwht_ref,
                                hadamard_matrix, randomized_fwht)
from repro.kernels.fwht.fwht import fwht_pallas


@pytest.mark.parametrize("block", [64, 256, 1024, 4096])
@pytest.mark.parametrize("rows", [1, 3, 32])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_matches_oracle(block, rows, dtype):
    key = jax.random.PRNGKey(block + rows)
    x = jax.random.normal(key, (rows, block), jnp.float32)
    ref = fwht_ref(x)
    out = fwht_pallas(x.astype(dtype).astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("block", [128, 512, 2048])
def test_mxu_form_matches_butterfly(block):
    x = jax.random.normal(jax.random.PRNGKey(0), (8, block))
    np.testing.assert_allclose(np.asarray(fwht_mxu_ref(x)),
                               np.asarray(fwht_ref(x)), atol=1e-4)


def test_involution():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 1024))
    np.testing.assert_allclose(np.asarray(fwht(fwht(x))), np.asarray(x),
                               atol=1e-4)


def test_hadamard_matrix_orthonormal():
    h = np.asarray(hadamard_matrix(64))
    np.testing.assert_allclose(h @ h.T, np.eye(64), atol=1e-5)


@given(st.integers(0, 2**31 - 1), st.sampled_from([256, 1024]))
def test_rht_roundtrip_property(seed, block):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (2, block))
    sign = jnp.where(jax.random.bernoulli(key, 0.5, (block,)), 1., -1.)
    enc = randomized_fwht(x, sign, mode="encode")
    dec = randomized_fwht(enc, sign, mode="decode")
    np.testing.assert_allclose(np.asarray(dec), np.asarray(x), atol=1e-4)


@given(st.integers(0, 2**31 - 1))
def test_energy_preservation(seed):
    """Orthonormal transform: ||Hx|| == ||x|| (what makes drop MSE bounded)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (1024,))
    y = fwht(x)
    np.testing.assert_allclose(float(jnp.sum(y * y)), float(jnp.sum(x * x)),
                               rtol=1e-4)


def test_linearity():
    """decode(mean(encode(g_i))) == mean(g_i): OptiReduce's exactness when
    no drops occur."""
    key = jax.random.PRNGKey(3)
    xs = jax.random.normal(key, (8, 2048))
    sign = jnp.where(jax.random.bernoulli(key, 0.5, (2048,)), 1., -1.)
    enc = jax.vmap(lambda v: randomized_fwht(v[None], sign,
                                             mode="encode")[0])(xs)
    dec = randomized_fwht(jnp.mean(enc, 0)[None], sign, mode="decode")[0]
    np.testing.assert_allclose(np.asarray(dec), np.asarray(jnp.mean(xs, 0)),
                               atol=1e-4)
