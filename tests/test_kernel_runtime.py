"""Kernel dispatch policy (kernels/runtime): precedence, validation, and the
interpret path's bit-exactness through the public kernel wrappers."""
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import runtime
from repro.kernels.fwht import fwht, randomized_fwht
from repro.kernels.fwht.fwht import fwht_pallas


@pytest.fixture(autouse=True)
def _clean_mode(monkeypatch):
    """Each test starts from the default policy (no override, no env)."""
    monkeypatch.delenv(runtime.ENV_VAR, raising=False)
    prev = runtime._explicit
    runtime.set_kernel_mode(None)
    yield
    runtime.set_kernel_mode(prev)


def test_default_mode_is_auto():
    assert runtime.kernel_mode() == "auto"


def test_auto_resolves_by_backend():
    want = "compile" if jax.default_backend() == "tpu" else "interpret"
    assert runtime.resolve() == want
    assert runtime.interpret_flag() == (want == "interpret")


def test_env_var_configures_mode(monkeypatch):
    monkeypatch.setenv(runtime.ENV_VAR, "interpret")
    assert runtime.kernel_mode() == "interpret"
    assert runtime.resolve() == "interpret"


def test_env_var_normalized(monkeypatch):
    monkeypatch.setenv(runtime.ENV_VAR, "  Interpret ")
    assert runtime.kernel_mode() == "interpret"


def test_explicit_beats_env(monkeypatch):
    monkeypatch.setenv(runtime.ENV_VAR, "compile")
    runtime.set_kernel_mode("interpret")
    assert runtime.kernel_mode() == "interpret"
    assert runtime.resolve() == "interpret"


def test_invalid_mode_rejected(monkeypatch):
    with pytest.raises(ValueError, match="unknown kernel mode"):
        runtime.set_kernel_mode("jit")
    monkeypatch.setenv(runtime.ENV_VAR, "hardware")
    with pytest.raises(ValueError, match="unknown kernel mode"):
        runtime.kernel_mode()


def test_compile_without_mosaic_is_a_clear_error():
    if jax.default_backend() == "tpu":
        pytest.skip("compile is legal on a TPU backend")
    runtime.set_kernel_mode("compile")
    with pytest.raises(RuntimeError, match="needs a TPU"):
        runtime.resolve()
    # and the error surfaces at dispatch time through a public wrapper too
    with pytest.raises(RuntimeError, match="needs a TPU"):
        fwht_pallas(jnp.zeros((2, 64), jnp.float32))


def test_scope_restores_previous_mode():
    runtime.set_kernel_mode("interpret")
    with runtime.kernel_mode_scope("auto"):
        assert runtime.kernel_mode() == "auto"
    assert runtime.kernel_mode() == "interpret"
    with pytest.raises(ValueError):
        with runtime.kernel_mode_scope("nope"):
            pass
    assert runtime.kernel_mode() == "interpret"


def test_resolution_logged_once(caplog):
    runtime.set_kernel_mode("interpret")   # resets the log-once latch
    with caplog.at_level(logging.INFO, logger="repro.kernels.runtime"):
        runtime.resolve()
        runtime.resolve()
        runtime.resolve()
    msgs = [r for r in caplog.records if "kernel dispatch" in r.getMessage()]
    assert len(msgs) == 1, msgs


def test_interpret_mode_bit_exact_to_explicit_flag():
    """kernel_mode='interpret' reproduces the historical interpret=True
    call-site behaviour bit-exactly through every dispatch layer."""
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (5, 256), jnp.float32)
    sign = jnp.where(
        jax.random.bernoulli(jax.random.fold_in(key, 1), shape=(256,)),
        1.0, -1.0).astype(jnp.float32)
    explicit = fwht_pallas(x, interpret=True)
    with runtime.kernel_mode_scope("interpret"):
        via_policy = fwht_pallas(x)
        via_ops = fwht(x, use_kernel=True)
        via_rand = randomized_fwht(x, sign, mode="encode", use_kernel=True)
    np.testing.assert_array_equal(np.asarray(via_policy),
                                  np.asarray(explicit))
    np.testing.assert_array_equal(np.asarray(via_ops), np.asarray(explicit))
    np.testing.assert_array_equal(
        np.asarray(via_rand),
        np.asarray(fwht_pallas(x * sign[None, :], interpret=True)))
