"""Launch-to-allreduce tests for the multi-process peer runtime
(repro/launch/multiproc.py).

The load-bearing claims (DESIGN §9): a multi-worker launch through the
rendezvous produces *bitwise* the same per-rank results as the
single-process :class:`~repro.net.HostRing` driver under the same scripted
loss; a worker crash mid-step lets the survivors complete that step
degraded and eject the corpse; a restarted worker is readmitted through
PROBATION and resumes from its checkpoint.  The inproc backend runs the
whole machinery in-process (threads over the LocalCoordinator — fast,
deterministic); the UDP path is the real thing: one OS process per rank,
TCP rendezvous, datagrams on localhost (slow-marked).
"""
import hashlib
import json
import os
import signal
import subprocess
import sys
import tempfile

import numpy as np
import pytest

from repro.core.pipeline import OptiReduceConfig
from repro.launch import multiproc as mp
from repro.net import HostRing, bernoulli_drops, udp_available

pytestmark = [pytest.mark.net, pytest.mark.multiproc]

needs_udp = pytest.mark.skipif(not udp_available(),
                               reason="sandbox forbids UDP sockets")

N, DROP_RATE, DROP_SEED = 4, 0.1, 3


def _checksum(a):
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()[:16]


def _reference_checksums(elems, steps, seed=0):
    """Per-rank per-step checksums from the single-process HostRing driver
    under the identical scripted wire (the parity oracle)."""
    import jax

    cfg = OptiReduceConfig(strategy="optireduce", drop_rate=0.0,
                           hadamard_block=256, packet_elems=256)
    ring = HostRing(N, cfg, backend="inproc",
                    drop_fn=bernoulli_drops(DROP_RATE, seed=DROP_SEED))
    out = {}
    for step in range(steps):
        data = np.random.default_rng(seed + step).standard_normal(
            (N, elems)).astype(np.float32)
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        res, _ = ring.allreduce(data, key, step=step, bucket=0)
        out[step] = [_checksum(np.asarray(res[r])) for r in range(N)]
    return out


def _by_rank(report):
    return {w["rank"]: w for w in report["workers"] if "steps" in w}


# ---------------------------------------------------------------- inproc
def test_inproc_launch_matches_hostring_bitwise(tmp_path):
    """4 launched workers over the LocalCoordinator == single-process
    HostRing, checksum-for-checksum."""
    elems, steps = 2048, 3
    report = mp.main(["--backend", "inproc", "--nprocs", str(N),
                      "--steps", str(steps), "--elems", str(elems),
                      "--drop-rate", str(DROP_RATE),
                      "--drop-seed", str(DROP_SEED)])
    ref = _reference_checksums(elems, steps)
    by_rank = _by_rank(report)
    assert sorted(by_rank) == list(range(N))
    for step in range(steps):
        got = [by_rank[r]["steps"][step]["checksum"] for r in range(N)]
        assert got == ref[step], f"step {step} diverged from HostRing"
        # stage-1 loss really flowed (scripted wire, not a lossless path)
        assert any(by_rank[r]["steps"][step]["loss_frac"] > 0
                   for r in range(N))


def test_inproc_crash_ejection_and_probation_readmission():
    """Thread-mode SIGKILL at step 1: the step completes degraded, the
    victim is ejected, its restart restores the checkpoint and walks
    EJECTED -> PROBATION -> ACTIVE in the survivors' detectors."""
    kill_rank, kill_step, steps = 1, 1, 6
    report = mp.main(["--backend", "inproc", "--nprocs", str(N),
                      "--steps", str(steps), "--elems", "1024",
                      "--drop-rate", str(DROP_RATE),
                      "--kill-rank", str(kill_rank),
                      "--kill-step", str(kill_step), "--restart"])
    killed = [w for w in report["workers"] if w.get("exit") == "killed"]
    assert len(killed) == 1 and killed[0]["rank"] == kill_rank
    by_rank = _by_rank(report)
    assert sorted(by_rank) == list(range(N))

    rejoiner = by_rank[kill_rank]
    assert rejoiner["resumed_from"] == kill_step - 1   # checkpointed step
    assert rejoiner["start_step"] > kill_step
    assert rejoiner["steps"][-1]["step"] == steps - 1

    for r in range(N):
        if r == kill_rank:
            continue
        recs = by_rank[r]["steps"]
        trail = [s["statuses"][kill_rank] for s in recs]
        # the kill step itself completes (degraded), ejection lands next
        assert trail[kill_step] == "active"
        assert recs[kill_step + 1]["skipped"] == [kill_rank]
        assert "ejected" in trail[kill_step + 1:]
        # the rejoin readmits through probation, never straight to active
        post = trail[trail.index("ejected"):]
        assert "probation" in post
        assert post.index("probation") < len(post) - 1 or \
            post[-1] == "probation"


# ------------------------------------------------------------------- udp
def _run_udp(argv, timeout):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "report.json")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.multiproc",
             "--report", path] + argv,
            env=env, timeout=timeout, capture_output=True, text=True)
        assert proc.returncode == 0, proc.stderr[-2000:]
        with open(path) as f:
            return json.load(f)


@pytest.mark.slow
@needs_udp
def test_udp_4proc_launch_matches_hostring_bitwise():
    """The acceptance pin: a 4-process UDP run over the TCP rendezvous is
    bitwise identical to the single-process inproc HostRing under the same
    scripted loss.  The generous --deadline keeps real wall-clock out of
    the arrival masks (a 0.25s deadline can expire under CPU contention
    from 4 concurrent jax processes, masking packets the script delivered).
    """
    elems, steps = 4096, 2
    report = _run_udp(["--backend", "udp", "--nprocs", str(N),
                       "--steps", str(steps), "--elems", str(elems),
                       "--drop-rate", str(DROP_RATE),
                       "--drop-seed", str(DROP_SEED),
                       "--deadline", "2.0", "--timeout", "240"],
                      timeout=300)
    ref = _reference_checksums(elems, steps)
    by_rank = _by_rank(report)
    assert sorted(by_rank) == list(range(N))
    for step in range(steps):
        got = [by_rank[r]["steps"][step]["checksum"] for r in range(N)]
        assert got == ref[step], f"step {step} diverged from HostRing"


@pytest.mark.slow
@needs_udp
def test_udp_sigkill_ejection_and_readmission():
    """Real SIGKILL mid-run: survivors eject the corpse and keep stepping;
    the relaunched process rejoins via the rendezvous, restores its
    checkpoint, and at least one survivor records its probationary
    readmission (detector re-ejection on real timing noise is legal)."""
    kill_rank, kill_step, steps = 1, 1, 12
    report = _run_udp(["--backend", "udp", "--nprocs", str(N),
                       "--steps", str(steps), "--elems", "1024",
                       "--drop-rate", "0.05", "--deadline", "1.0",
                       "--step-sleep", "2", "--kill-rank", str(kill_rank),
                       "--kill-step", str(kill_step), "--restart",
                       "--timeout", "400"],
                      timeout=480)
    assert report["scenario"]["kill_rank"] == kill_rank
    killed = [w for w in report["workers"] if w.get("exit") == "killed"]
    assert len(killed) == 1
    by_rank = _by_rank(report)
    assert sorted(by_rank) == list(range(N))

    rejoiner = by_rank[kill_rank]
    assert rejoiner["resumed_from"] == kill_step - 1
    assert rejoiner["start_step"] > kill_step
    assert rejoiner["steps"][-1]["step"] == steps - 1

    survivors = [by_rank[r] for r in range(N) if r != kill_rank]
    for w in survivors:
        trail = [s["statuses"][kill_rank] for s in w["steps"]]
        assert "ejected" in trail[kill_step:]
        assert w["steps"][-1]["step"] == steps - 1
    # the kill step completed degraded everywhere (no survivor aborted it)
    assert all(any(s["step"] == kill_step for s in w["steps"])
               for w in survivors)
    assert any("probation" in [s["statuses"][kill_rank] for s in w["steps"]]
               for w in survivors)


def test_sigkill_helper_uses_sigkill():
    """The scripted kill must be a real SIGKILL (no atexit, no TCP FIN) —
    the rendezvous EOF/heartbeat path is what detects it."""
    src = mp._sigkill_self.__code__.co_names
    assert "SIGKILL" in src and "kill" in src
    assert signal.SIGKILL == 9
