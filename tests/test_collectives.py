"""Multi-device collective equivalence tests.

These need >1 XLA device, so they run in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main pytest
process keeps the default single device, per the dry-run-only-512 rule).
"""
import os
import subprocess
import sys

import pytest

CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, shard_map
from repro.core import OptiReduceConfig, SyncContext, sync_bucket
from repro.core.allreduce import reduce_scatter_axis

mesh = make_mesh((8,), ("data",))
L = 10_000
key = jax.random.PRNGKey(0)
xs = jax.random.normal(key, (8, L), jnp.float32)
expected = np.asarray(jnp.mean(xs, axis=0))

def run(strategy, drop_rate=0.0, block=1024):
    cfg = OptiReduceConfig(strategy=strategy, drop_rate=drop_rate,
                           hadamard_block=block)
    def body(x):
        ctx = SyncContext(cfg=cfg, key=jax.random.PRNGKey(42))
        return sync_bucket(x.reshape(-1), ctx)[None, :]
    f = jax.jit(shard_map(body, mesh=mesh, in_specs=P("data", None),
                              out_specs=P("data", None), check_vma=False))
    return np.asarray(f(xs))

# 1) every lossless strategy computes the exact mean, replica-consistent
for s in ("psum", "gloo_ring", "nccl_tree", "bcube", "tar_tcp",
          "tar_rounds", "optireduce"):
    out = run(s)
    err = np.max(np.abs(out - expected[None]))
    spread = np.max(np.abs(out - out[0:1]))
    assert err < 1e-5, (s, err)
    assert spread == 0.0, (s, spread)
print("lossless-equivalence OK")

# 2) drops: bounded error, replicas stay identical (stage-1-only drops)
out = run("optireduce", drop_rate=0.05)
rmse = np.sqrt(np.mean((out[0] - expected) ** 2))
spread = np.max(np.abs(out - out[0:1]))
assert 0 < rmse < 0.3, rmse
assert spread == 0.0, spread
print("drop-consistency OK")

# 3) reduce_scatter_axis == sliced mean (the FSDP/ZeRO reduction)
g = jax.random.normal(key, (8, 64, 48))
def rs_body2(x):
    ctx = SyncContext(cfg=OptiReduceConfig(drop_rate=0.0),
                      key=jax.random.PRNGKey(1))
    i = jax.lax.axis_index("data")
    local = jnp.take(x, i, axis=0)     # worker i's gradient (64, 48)
    return reduce_scatter_axis(local, "data", 0, ctx, with_drops=False)
f2 = jax.jit(shard_map(rs_body2, mesh=mesh,
                           in_specs=P(None, None, None),
                           out_specs=P("data", None),
                           check_vma=False))
out2 = np.asarray(f2(g))              # (64, 48): stacked shards
np.testing.assert_allclose(out2, np.asarray(jnp.mean(g, 0)), atol=1e-5)
print("reduce-scatter OK")

# 4) 2D TAR on a (2, 2, 2) pod mesh
mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg2 = OptiReduceConfig(strategy="optireduce", pod_axis="pod",
                        drop_rate=0.0, hadamard_block=256)
xs2 = jax.random.normal(key, (4, 2048), jnp.float32)   # per (pod,data)
def body2(x):
    ctx = SyncContext(cfg=cfg2, key=jax.random.PRNGKey(3))
    return sync_bucket(x.reshape(-1), ctx)[None]
f3 = jax.jit(shard_map(
    body2, mesh=mesh3, in_specs=P(("pod", "data"), None),
    out_specs=P(("pod", "data"), None), check_vma=False))
out3 = np.asarray(f3(xs2))           # (4, 2048): identical rows
assert np.max(np.abs(out3 - np.asarray(jnp.mean(xs2, 0))[None])) < 1e-5
assert np.max(np.abs(out3 - out3[0:1])) == 0.0
print("2d-tar OK")

# 5) trainer integration: fsdp == replicated (lossless), losses decrease
from repro.configs.base import ModelConfig
from repro.optim.optimizers import OptimizerConfig
from repro.train.trainer import TrainConfig, build_train_step
from repro.models import init_params
cfg_m = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                    n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                    param_dtype=jnp.float32)
mesh2 = make_mesh((4, 2), ("data", "model"))
batch = {"tokens": jax.random.randint(key, (8, 16), 0, 128),
         "labels": jax.random.randint(key, (8, 16), 0, 128)}
losses = {}
for mode in ("replicated", "fsdp"):
    tc = TrainConfig(sync=OptiReduceConfig(strategy="optireduce",
                                           drop_rate=0.0,
                                           hadamard_block=256),
                     optimizer=OptimizerConfig(lr=1e-2),
                     dp_mode=mode, seq_chunk=16)
    make_step, opt, _ = build_train_step(cfg_m, tc, mesh2)
    params = init_params(key, cfg_m)
    step_fn, sh = make_step(jax.eval_shape(opt.init, params), batch)
    params = jax.device_put(params, sh["params"])
    opt_state = jax.jit(opt.init, out_shardings=sh["opt"])(params)
    b = jax.device_put(batch, sh["batch"])
    jf = jax.jit(step_fn)
    ls = []
    for i in range(4):
        params, opt_state, m = jf(params, opt_state, b,
                                  jnp.asarray(i, jnp.int32), key)
        ls.append(float(m["loss"]))
    losses[mode] = ls
    assert ls[-1] < ls[0], (mode, ls)
# identical math when lossless: fsdp path == replicated path
np.testing.assert_allclose(losses["fsdp"], losses["replicated"], rtol=2e-3)
print("trainer-equivalence OK")
"""


@pytest.mark.slow
def test_collectives_multidevice():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", CHILD], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    for marker in ("lossless-equivalence OK", "drop-consistency OK",
                   "reduce-scatter OK", "2d-tar OK",
                   "trainer-equivalence OK"):
        assert marker in proc.stdout, proc.stdout
