"""Per-arch smoke tests (deliverable f): every assigned architecture's
REDUCED same-family config runs one forward/train step on CPU with correct
shapes and no NaNs; decoder archs also run a decode step."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_smoke
from repro.models import (SINGLE, decode_step, init_decode_state,
                          init_params, lm_loss, prefill_step)

ALL = list(ARCHS) + ["gpt2-paper"]


def _batch(cfg, key, b=2, s=16):
    out = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
           "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.frontend:
        p = min(cfg.prefix_len, 8)
        out["prefix_embeds"] = jax.random.normal(
            key, (b, p, cfg.frontend_dim))
    return out


@pytest.mark.parametrize("arch", ALL)
def test_train_step_smoke(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)
    loss, grads = jax.value_and_grad(
        lambda p: lm_loss(p, batch, cfg, SINGLE, key=key, seq_chunk=16))(
            params)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss))
    assert not any(bool(jnp.isnan(g).any()) for g in jax.tree.leaves(grads))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ALL)
def test_decode_step_smoke(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    state = init_decode_state(params, cfg, batch=2, max_seq=8,
                              dtype=cfg.param_dtype)
    tok = jax.random.randint(key, (2, 1), 0, cfg.vocab_size)
    nxt, new_state = decode_step(params, state, tok, jnp.int32(0), cfg,
                                 SINGLE, key=key)
    assert nxt.shape == (2, 1)
    assert nxt.dtype == jnp.int32
    assert int(nxt.min()) >= 0 and int(nxt.max()) < cfg.vocab_size
    # state structure preserved
    assert len(jax.tree.leaves(new_state)) == len(jax.tree.leaves(state))


@pytest.mark.parametrize("arch", ["gpt2-paper", "mamba2-1.3b",
                                  "jamba-v0.1-52b"])
def test_prefill_then_decode_consistency(arch):
    """Greedy decode after prefill == greedy decode after teacher-forced
    step-by-step decoding of the same prompt."""
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    prompt = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)

    tok_pf, state_pf = prefill_step(params, {"tokens": prompt}, cfg, SINGLE,
                                    key=key)

    state = init_decode_state(params, cfg, batch=1, max_seq=8,
                              dtype=cfg.param_dtype)
    tok = prompt[:, :1]
    for t in range(8):
        nxt, state = decode_step(params, state, tok, jnp.int32(t), cfg,
                                 SINGLE, key=key)
        tok = prompt[:, t + 1:t + 2] if t + 1 < 8 else nxt
    assert int(tok_pf[0, 0]) == int(tok[0, 0])
