"""Property tests for the wire packet codec (repro/net/wire.py).

The load-bearing invariants: header encode/decode is a lossless roundtrip,
reassembly is invariant under arbitrary arrival permutation + duplication,
a missing seq produces exactly the mask ``core/drops.py`` would expand for
that packet span (including the short tail fragment when
``payload % packet_elems != 0``), and the observed ``loss_fraction`` agrees
with the drops-module accounting on the same mask.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, strategies as st
except ImportError:
    from _hypothesis_fallback import given, strategies as st

from repro.core import drops as drops_lib
from repro.net import (HEADER_BYTES, KIND_CTRL, KIND_DATA1, KIND_DATA2,
                       PacketHeader, Reassembly, WireError, n_packets,
                       packetize)

pytestmark = pytest.mark.net


@given(st.sampled_from([KIND_DATA1, KIND_DATA2, KIND_CTRL]),
       st.integers(0, 65535), st.integers(0, 2**32 - 1),
       st.integers(0, 65535), st.integers(0, 65534))
def test_header_roundtrip(kind, sender, step, rnd, seq):
    hdr = PacketHeader(kind=kind, sender=sender, step=step, bucket=7,
                       round=rnd, seq=seq, n_seq=max(seq + 1, 1))
    blob = hdr.encode() + b"payload"
    back, payload = PacketHeader.decode(blob)
    assert back == hdr
    assert bytes(payload) == b"payload"
    assert len(hdr.encode()) == HEADER_BYTES


def test_header_rejects_garbage():
    with pytest.raises(WireError):
        PacketHeader.decode(b"short")
    hdr = PacketHeader(kind=KIND_DATA1, sender=0, step=0, bucket=0,
                       round=1, seq=0, n_seq=1)
    bad_version = bytes([99]) + hdr.encode()[1:]
    with pytest.raises(WireError):
        PacketHeader.decode(bad_version)
    bad_kind = hdr.encode()[:1] + bytes([77]) + hdr.encode()[2:]
    with pytest.raises(WireError):
        PacketHeader.decode(bad_kind)


def _stream(n_elems, packet_elems, dtype=np.float32, seed=0):
    payload = np.random.default_rng(seed).standard_normal(n_elems)
    payload = payload.astype(dtype) if dtype != np.uint8 else \
        (np.abs(payload) * 50).astype(np.uint8)
    pkts = packetize(payload, kind=KIND_DATA1, sender=3, step=1, bucket=2,
                     round=1, packet_elems=packet_elems)
    return payload, pkts


@given(st.integers(1, 700), st.sampled_from([1, 3, 64, 256]),
       st.integers(0, 6))
def test_reassembly_permutation_and_duplication(n_elems, packet_elems, seed):
    """Any arrival order, with duplicates, rebuilds the exact payload with
    an all-ones mask — including the tail-fragment edge."""
    payload, pkts = _stream(n_elems, packet_elems, seed=seed)
    order = np.random.default_rng(seed).permutation(len(pkts))
    arrivals = [pkts[i] for i in order] + [pkts[i] for i in order[:2]]
    reas = Reassembly(n_elems, payload.dtype, packet_elems)
    for dgram in arrivals:
        hdr, frag = PacketHeader.decode(dgram)
        reas.add(hdr, frag)
    assert reas.complete
    assert reas.duplicates == min(2, len(pkts))
    np.testing.assert_array_equal(reas.payload(), payload)
    np.testing.assert_array_equal(reas.mask(), np.ones(n_elems, np.float32))


@given(st.integers(2, 9), st.sampled_from([1, 2, 5]), st.integers(0, 5))
def test_missing_seq_mask_matches_drops_expansion(n_pkts_target, pe, seed):
    """Dropping seq set S yields exactly the mask drops._expand would give
    the corresponding packet mask, and loss_fraction agrees."""
    import jax.numpy as jnp
    n_elems = n_pkts_target * pe - (seed % pe)       # exercise tail fragments
    n_elems = max(n_elems, 1)
    payload, pkts = _stream(n_elems, pe, seed=seed)
    total = n_packets(n_elems, pe)
    rng = np.random.default_rng(seed + 100)
    keep = rng.random(total) > 0.4
    if keep.all():
        keep[rng.integers(total)] = False
    reas = Reassembly(n_elems, payload.dtype, pe)
    for i, dgram in enumerate(pkts):
        if keep[i]:
            hdr, frag = PacketHeader.decode(dgram)
            reas.add(hdr, frag)
    assert not reas.complete
    # the reference expansion drops.py applies to packet-granular masks
    expect = np.repeat(keep.astype(np.float32), pe)[:n_elems]
    np.testing.assert_array_equal(reas.mask(), expect)
    # arrived spans carry exact bytes; missing spans read zero
    np.testing.assert_array_equal(reas.payload()[expect == 1.0],
                                  payload[expect == 1.0])
    assert not np.any(reas.payload()[expect == 0.0])
    # and the loss accounting agrees with core/drops.loss_fraction
    got = float(drops_lib.loss_fraction(jnp.asarray(reas.mask()[None, :])))
    want = 1.0 - expect.mean()
    assert got == pytest.approx(want, abs=1e-6)


def test_reassembly_rejects_wrong_geometry_and_sizes():
    payload, pkts = _stream(100, 30)                  # 4 packets, tail of 10
    reas = Reassembly(100, np.float32, 30)
    hdr, frag = PacketHeader.decode(pkts[0])
    # wrong n_seq (different geometry) is not this stream's packet
    bad = PacketHeader(kind=hdr.kind, sender=hdr.sender, step=hdr.step,
                       bucket=hdr.bucket, round=hdr.round, seq=0, n_seq=9)
    assert not reas.add(bad, frag)
    # truncated fragment is garbage
    assert not reas.add(hdr, frag[:-4])
    # tail fragment must be short (10 elems), not padded
    tail_hdr, tail_frag = PacketHeader.decode(pkts[-1])
    assert len(tail_frag) == 10 * 4
    assert reas.add(tail_hdr, tail_frag)
    assert reas.frac_received() == pytest.approx(0.25)


def test_packetize_roundtrip_uint8_codes():
    """The quantized wire path: uint8 codes, odd length, order-free."""
    payload, pkts = _stream(1003, 256, dtype=np.uint8, seed=4)
    reas = Reassembly(1003, np.uint8, 256)
    for dgram in reversed(pkts):
        hdr, frag = PacketHeader.decode(dgram)
        assert reas.add(hdr, frag)
    assert reas.complete
    np.testing.assert_array_equal(reas.payload(), payload)
