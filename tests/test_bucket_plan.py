"""BucketPlan / fused sync_pytree: layout bookkeeping, bitwise regression
against the seed bucketing loop, and the constant-HLO-in-B property the
scan rewrite exists for. Multi-worker bitwise equivalence runs in a
subprocess (same pattern as test_collectives.py)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BucketPlan, OptiReduceConfig, SyncContext,
                        sync_pytree, sync_pytree_unfused)
from repro.compat import make_mesh, shard_map
from jax.sharding import PartitionSpec as P


def _tree(key, sizes):
    ks = jax.random.split(key, len(sizes))
    return {f"leaf{i}": jax.random.normal(k, s)
            for i, (k, s) in enumerate(zip(ks, sizes))}


def test_plan_layout_and_hashability():
    tree = _tree(jax.random.PRNGKey(0), [(3, 500), (700,), (9, 100)])
    plan = BucketPlan.for_tree(tree, 1000)
    assert plan.total == 3100
    assert plan.num_buckets == 4
    assert plan.padded == 4000
    assert plan.sizes == (1500, 700, 900)
    # hashable + stable across rebuilds from the same shapes
    assert hash(plan) == hash(BucketPlan.for_tree(tree, 1000))
    assert plan == BucketPlan.for_tree(jax.tree.map(jnp.zeros_like, tree),
                                       1000)


def test_plan_single_bucket_has_no_tail_padding():
    tree = _tree(jax.random.PRNGKey(1), [(40,), (60,)])
    plan = BucketPlan.for_tree(tree, 6_553_600)
    assert plan.num_buckets == 1 and plan.bucket_elems == 100
    assert plan.padded == plan.total


def test_pack_unpack_roundtrip_preserves_dtype():
    tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (17, 13),
                                   jnp.float32),
            "b": jax.random.normal(jax.random.PRNGKey(1),
                                   (300,)).astype(jnp.bfloat16)}
    plan = BucketPlan.for_tree(tree, 128)
    out = plan.unpack(plan.pack(tree))
    assert out["b"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))
    np.testing.assert_array_equal(
        np.asarray(out["b"].astype(jnp.float32)),
        np.asarray(tree["b"].astype(jnp.float32)))


def _sync(fn, tree, cfg, bucket_elems, **kw):
    """Run a sync function under a dp=1 shard_map (single device)."""
    mesh = make_mesh((1,), ("data",))
    spec = jax.tree.map(lambda _: P(), tree)

    def body(t):
        ctx = SyncContext(cfg=cfg, key=jax.random.PRNGKey(5))
        return fn(t, ctx, bucket_elems=bucket_elems, **kw)

    f = jax.jit(shard_map(body, mesh=mesh, in_specs=(spec,), out_specs=spec,
                          check_vma=False))
    return f, f(tree)


@pytest.mark.parametrize("strategy", ["psum", "optireduce", "optireduce_q"])
def test_bitwise_matches_seed_bucketing(strategy):
    """Fused (scan) sync_pytree == seed loop, bitwise, on a multi-leaf
    pytree spanning >= 3 buckets.

    psum/optireduce are deterministic and elementwise across peers, so the
    identity holds even with a zero-padded tail bucket; optireduce_q draws
    shape-dependent stochastic-rounding noise, so it is exercised on a
    layout whose tail bucket is full (the padded-tail case is equivalent in
    distribution, not bitwise)."""
    sizes = ([(3, 500), (600,), (9, 100)] if strategy == "optireduce_q"
             else [(3, 500), (700,), (9, 100)])
    tree = _tree(jax.random.PRNGKey(2), sizes)
    cfg = OptiReduceConfig(strategy=strategy, drop_rate=0.0,
                           hadamard_block=256)
    _, ref = _sync(sync_pytree_unfused, tree, cfg, 1000)
    _, out = _sync(sync_pytree, tree, cfg, 1000)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(ref[k]), np.asarray(out[k]))


@pytest.mark.parametrize("accum_dtype", [jnp.float32, jnp.bfloat16])
def test_arena_accumulation_bitwise_vs_per_leaf(accum_dtype):
    """The trainer's packed gradient arena — a ``lax.scan`` accumulating
    micro-batch grads directly into the (B, bucket_elems) batch, the pack
    concat fused into the add — is bitwise-identical to the seed per-leaf
    ``zeros`` + ``tree.map`` scan accumulator followed by a final pack (the
    cast-then-concatenate commutes with the adds elementwise), and the full
    microbatch pipeline (accumulate, fp32 cast, /n_micro mean) matches the
    per-leaf formulation of the same math.

    (The mean is taken in fp32 wire space on both sides: a divide in a
    non-fp32 accum dtype is not XLA-stable across formulations — the
    simplifier rewrites divide->convert chains and reciprocal multiplies
    differently per fusion context — which is why the trainer casts before
    dividing.)"""
    n_micro = 3
    sizes = [(3, 500), (700,), (9, 100)]
    micro_list = [_tree(jax.random.PRNGKey(10 + i), sizes)
                  for i in range(n_micro)]
    gs = jax.tree.map(lambda *xs: jnp.stack(xs), *micro_list)
    plan = BucketPlan.for_tree(micro_list[0], 1000)

    @jax.jit
    def seed_path(gs):
        def micro(acc, g):
            return jax.tree.map(
                lambda a, b: a + b.astype(accum_dtype), acc, g), None
        zeros = jax.tree.map(
            lambda g: jnp.zeros(g.shape[1:], accum_dtype), gs)
        acc, _ = jax.lax.scan(micro, zeros, gs)
        return plan.pack(acc), plan.pack(acc) / n_micro

    @jax.jit
    def arena_path(gs):
        def micro(acc, g):
            return acc + plan.pack(g, dtype=accum_dtype), None
        arena0 = jnp.zeros((plan.num_buckets, plan.bucket_elems),
                           accum_dtype)
        arena, _ = jax.lax.scan(micro, arena0, gs)
        return arena.astype(jnp.float32), arena.astype(jnp.float32) / n_micro

    seed_acc, seed_mean = seed_path(gs)
    arena_acc, arena_mean = arena_path(gs)
    np.testing.assert_array_equal(np.asarray(seed_acc), np.asarray(arena_acc))
    np.testing.assert_array_equal(np.asarray(seed_mean),
                                  np.asarray(arena_mean))


def test_plan_offsets_cover_stream():
    tree = _tree(jax.random.PRNGKey(4), [(3, 500), (700,), (9, 100)])
    plan = BucketPlan.for_tree(tree, 1000)
    assert plan.offsets == (0, 1500, 2200)
    assert plan.offsets[-1] + plan.sizes[-1] == plan.total


@pytest.mark.parametrize("mode", ["vmap", "pipelined"])
def test_alternate_modes_match_scan(mode):
    tree = _tree(jax.random.PRNGKey(3), [(2048,), (2048,)])
    cfg = OptiReduceConfig(strategy="optireduce", drop_rate=0.0,
                           hadamard_block=256)
    _, a = _sync(sync_pytree, tree, cfg, 1024)
    _, b = _sync(sync_pytree, tree, cfg, 1024, mode=mode)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


@pytest.mark.parametrize("nbuckets", [1, 2, 3, 4, 8])
def test_pipelined_mode_every_pipeline_shape(nbuckets):
    """Depth-2 skew across every scheduling shape: B=1/2 (skew deeper than
    bucket count, fully unrolled), B=3 (empty steady-state window), B=4
    (single-step scan), B=8 (steady state) — all bitwise vs scan mode."""
    tree = {"g": jax.random.normal(jax.random.PRNGKey(6), (nbuckets * 1024,))}
    cfg = OptiReduceConfig(strategy="optireduce", drop_rate=0.0,
                           hadamard_block=256)
    _, a = _sync(sync_pytree, tree, cfg, 1024)
    _, b = _sync(sync_pytree, tree, cfg, 1024, mode="pipelined")
    np.testing.assert_array_equal(np.asarray(a["g"]), np.asarray(b["g"]))


def test_hlo_size_constant_in_bucket_count():
    """The strategy body is traced once: the lowered module carries ONE
    collective (inside the scan) regardless of B, where the seed loop
    emits one per bucket — and overall HLO size stays ~flat in B."""
    cfg = OptiReduceConfig(strategy="optireduce", drop_rate=0.0,
                           hadamard_block=256)

    def lowered(fn, nbuckets):
        tree = {"a": jnp.zeros((nbuckets * 1024,))}
        f, _ = _sync(fn, tree, cfg, 1024)
        return f.lower(tree).as_text()

    def n_a2a(txt):
        return txt.count("all_to_all")

    assert n_a2a(lowered(sync_pytree, 8)) == n_a2a(lowered(sync_pytree, 2))
    assert (n_a2a(lowered(sync_pytree_unfused, 8))
            == 4 * n_a2a(lowered(sync_pytree_unfused, 2)))
    fused_growth = (len(lowered(sync_pytree, 8))
                    / len(lowered(sync_pytree, 2)))
    assert fused_growth < 1.35, fused_growth


CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, shard_map
from repro.core import OptiReduceConfig, SyncContext, sync_pytree, \
    sync_pytree_unfused

mesh = make_mesh((8,), ("data",))
key = jax.random.PRNGKey(0)
tree = {"w": jax.random.normal(key, (4, 1024)),
        "b": jax.random.normal(jax.random.fold_in(key, 1), (2048,)),
        "v": jax.random.normal(jax.random.fold_in(key, 2), (2048,))}
spec = jax.tree.map(lambda _: P(), tree)

def run(fn, cfg):
    def body(t):
        ctx = SyncContext(cfg=cfg, key=jax.random.PRNGKey(5))
        out = fn(t, ctx, bucket_elems=1024)
        return out, ctx.loss_fraction()
    f = jax.jit(shard_map(body, mesh=mesh, in_specs=(spec,),
                          out_specs=(spec, P()), check_vma=False))
    return f(tree)

# 8 full 1024-elem buckets: drops + kernels + quantized exchange, bitwise
for strat, dr, uk in (("optireduce", 0.1, False), ("optireduce", 0.1, True),
                      ("optireduce_q", 0.05, True)):
    cfg = OptiReduceConfig(strategy=strat, drop_rate=dr, hadamard_block=256,
                           use_kernels=uk)
    ref, ref_frac = run(sync_pytree_unfused, cfg)
    out, out_frac = run(sync_pytree, cfg)
    for k in tree:
        assert np.array_equal(np.asarray(ref[k]), np.asarray(out[k])), \
            (strat, uk, k)
    np.testing.assert_allclose(float(ref_frac), float(out_frac), atol=1e-6)
    print(strat, "uk=%s" % uk, "bitwise OK, loss_frac %.4f" % float(out_frac))
"""


@pytest.mark.slow
def test_bucket_plan_multidevice_bitwise():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", CHILD], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert proc.stdout.count("bitwise OK") == 3, proc.stdout
