"""Drop-compensated shard reduction: kernel parity + unbiasedness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, strategies as st
except ImportError:
    from _hypothesis_fallback import given, strategies as st

from repro.kernels.masked_sum import masked_mean, masked_mean_ref


@pytest.mark.parametrize("n", [2, 8, 16])
@pytest.mark.parametrize("length", [100, 2048, 5000])
def test_kernel_matches_oracle(n, length):
    key = jax.random.PRNGKey(n * length)
    x = jax.random.normal(key, (n, length))
    m = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.8,
                             (n, length)).astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(masked_mean(x, m, use_kernel=True)),
        np.asarray(masked_mean_ref(x, m)), atol=1e-6)


def test_no_mask_is_mean():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 1000))
    m = jnp.ones_like(x)
    np.testing.assert_allclose(np.asarray(masked_mean_ref(x, m)),
                               np.asarray(jnp.mean(x, 0)), atol=1e-6)


def test_all_dropped_is_zero():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    m = jnp.zeros_like(x)
    assert float(jnp.max(jnp.abs(masked_mean_ref(x, m)))) == 0.0


@given(st.integers(0, 2**31 - 1), st.floats(0.05, 0.5))
def test_unbiasedness(seed, rate):
    """E[compensated mean] == true mean when drops are value-independent
    (the §3.3 estimator property)."""
    rng = np.random.default_rng(seed)
    n, L, trials = 8, 64, 400
    x = rng.standard_normal((n, L)).astype(np.float32)
    true = x.mean(0)
    acc = np.zeros(L)
    for t in range(trials):
        m = (rng.random((n, L)) > rate).astype(np.float32)
        acc += np.asarray(masked_mean_ref(jnp.asarray(x), jnp.asarray(m)))
    est = acc / trials
    # standard error of the estimate shrinks with trials; loose 5-sigma band
    assert np.max(np.abs(est - true)) < 0.5
