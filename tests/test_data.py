"""Synthetic data pipeline: determinism, host sharding, resumability."""
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLM


def test_deterministic_across_instances():
    a = SyntheticLM(DataConfig(vocab_size=100, seq_len=16, global_batch=8))
    b = SyntheticLM(DataConfig(vocab_size=100, seq_len=16, global_batch=8))
    np.testing.assert_array_equal(a.global_batch(5)["tokens"],
                                  b.global_batch(5)["tokens"])


def test_host_shards_partition_global():
    data = SyntheticLM(DataConfig(vocab_size=100, seq_len=16,
                                  global_batch=8))
    g = data.global_batch(3)
    parts = [data.host_batch(3, h, 4) for h in range(4)]
    stitched = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(stitched, g["tokens"])


def test_labels_are_shifted_tokens():
    data = SyntheticLM(DataConfig(vocab_size=100, seq_len=16,
                                  global_batch=2))
    b = data.global_batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_grammar_signal_exists():
    """The Markov structure must be learnable: successor pairs repeat."""
    data = SyntheticLM(DataConfig(vocab_size=64, seq_len=512,
                                  global_batch=4, markov_weight=0.9,
                                  n_succ=1))
    b = data.global_batch(0)
    tok, lab = b["tokens"], b["labels"]
    # for deterministic successors, P(label == succ[token]) ~ markov_weight
    hits = np.mean(lab == data.succ[tok, 0])
    assert hits > 0.75
