"""The wire subsystem's load-bearing correctness pin (ISSUE acceptance):

given identical arrival masks, the *wire-exchanged* TAR result — every
byte really crossing the inproc backend, scripted to drop exactly the
packets the in-JAX ``Lossy`` transport's ``core/drops.py`` masks name — is
**bitwise-identical** to the in-JAX result, for registered strategies
including a quantized one (grid pmax reproduced by wire max-sharing), and
the ``WireTransport`` io_callback bridge feeding those observed masks into
the in-JAX datapath hits the same bits too.

Runs in ONE subprocess (4 forced host devices, same pattern as
test_pipeline_parity.py); parametrized tests assert per-strategy markers.
"""
import os
import subprocess
import sys

import pytest

# (strategy, drop_rate, use_kernels): Hadamard, rounds-scheduled quantized,
# and the kernel-dispatched quantized a2a path
STRATEGIES = [
    ("optireduce", 0.1, False),
    ("tar_rounds_q", 0.05, False),
    ("optireduce_q", 0.05, True),
]

CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, shard_map
from repro.core.allreduce import OptiReduceConfig, SyncContext, sync_bucket
from repro.core import drops as drops_lib, tar as tar_lib
from repro.core.pipeline import resolve_spec
from repro.net import HostRing, InprocBackend, mask_scripted_drops, wire_spec

N, L = 4, 1000            # not block-aligned: pad + tail-packet paths on
mesh = make_mesh((N,), ("data",))
key = jax.random.PRNGKey(5)
buckets = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (N, L)),
                     np.float32)

def run(cfg, spec=None):
    def body(x):
        ctx = SyncContext(cfg=cfg, key=key)
        return sync_bucket(x[0], ctx, spec=spec), ctx.loss_fraction()
    f = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("data"),),
                          out_specs=(P("data"), P()), check_vma=False))
    out, frac = f(buckets)
    return np.asarray(out).reshape(N, L), float(frac)

def lossy_masks(cfg):
    padded, _ = tar_lib.pad_for_tar(jnp.zeros(L), N,
                                    resolve_spec(cfg).codec.block(cfg))
    s = padded.shape[0] // N
    return {me: np.asarray(drops_lib.make_mask(
        cfg.drop_pattern, jax.random.fold_in(key, me), N, s,
        rate=cfg.drop_rate, packet_elems=cfg.packet_elems,
        self_index=jnp.asarray(me))) for me in range(N)}

for strat, dr, uk in %(strategies)r:
    cfg = OptiReduceConfig(strategy=strat, drop_rate=dr, hadamard_block=256,
                           use_kernels=uk, quant_bits=8, packet_elems=64,
                           incast=2)
    ref, ref_frac = run(cfg)
    masks = lossy_masks(cfg)
    drop_fn = mask_scripted_drops(masks, cfg.packet_elems)

    # --- host datapath: every byte over the wire, scripted drops ---------
    ring = HostRing(N, cfg, backend=InprocBackend(N, drop_fn=drop_fn))
    out, tel = ring.allreduce(buckets, key)
    assert np.array_equal(out, ref), (strat, "host datapath")
    assert abs(tel.loss_frac - ref_frac) < 1e-6, (strat, tel.loss_frac,
                                                  ref_frac)
    assert len(tel.peer_stage_times) == N
    print("WIRE_PARITY %%s OK loss_frac=%%.5f" %% (strat, tel.loss_frac))

    # --- io_callback bridge: in-JAX datapath, wire-observed masks --------
    # (the bridge is one-exchange lagged: call 0 primes with all-ones; the
    # scripted loss is a pure function of the packet header, so call 1
    # consumes exchange 0's masks == the Lossy masks, bitwise)
    cfg_w = OptiReduceConfig(strategy=strat, drop_rate=0.0,
                             hadamard_block=256, use_kernels=uk,
                             quant_bits=8, packet_elems=64, incast=2)
    bridge_ring = HostRing(N, cfg_w,
                           backend=InprocBackend(N, drop_fn=drop_fn))
    wspec = wire_spec(cfg_w, bridge_ring)
    _, prime_frac = run(cfg_w, spec=wspec)
    assert prime_frac == 0.0, (strat, "priming call must see no loss")
    assert bridge_ring.flush()
    wout, wfrac = run(cfg_w, spec=wspec)
    assert np.array_equal(wout, ref), (strat, "bridge")
    assert abs(wfrac - ref_frac) < 1e-6, (strat, wfrac, ref_frac)
    assert bridge_ring.bridge_misses == 0
    assert bridge_ring.flush()
    wt = bridge_ring.drain_telemetry()
    assert wt is not None and len(wt.peer_stage_times) == N
    print("BRIDGE_PARITY %%s OK" %% strat)

print("ALL_WIRE_PARITY_OK")
"""

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_marker_cache: dict = {}


def _child_output() -> str:
    if "out" not in _marker_cache:
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(_REPO, "src"), env.get("PYTHONPATH", "")])
        proc = subprocess.run(
            [sys.executable, "-c", CHILD % {"strategies": STRATEGIES}],
            env=env, capture_output=True, text=True, timeout=900)
        _marker_cache["out"] = proc.stdout + "\n" + proc.stderr + \
            f"\nreturncode={proc.returncode}"
        _marker_cache["rc"] = proc.returncode
    return _marker_cache["out"]


@pytest.mark.slow
@pytest.mark.parity
@pytest.mark.net
@pytest.mark.parametrize("strategy", [s for s, _, _ in STRATEGIES])
def test_wire_vs_lossy_bitwise(strategy):
    out = _child_output()
    assert _marker_cache["rc"] == 0, out
    assert f"WIRE_PARITY {strategy} OK" in out, out
    assert f"BRIDGE_PARITY {strategy} OK" in out, out


@pytest.mark.slow
@pytest.mark.parity
@pytest.mark.net
def test_wire_parity_suite_completed():
    out = _child_output()
    assert "ALL_WIRE_PARITY_OK" in out, out
