"""CI smoke for the loss-recovery bench: ``python -m benchmarks.run
--only bench_recovery`` in quick mode must keep producing the schema the
PR-over-PR trajectory diffs consume — cumulative-update MSE medians per
(pattern, rate, mechanism) with ``_mse_iqr`` dispersion siblings — and the
semantic claim DESIGN §8 makes: error feedback strictly beats zero-fill
at every swept loss rate, including 1% bursty loss.

Writes to a tmpdir via ``REPRO_BENCH_DIR`` so a test run never rewrites the
checked-in BENCH_recovery.json baseline.
"""
import json
import os
import subprocess
import sys

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_bench_recovery_quick_schema_and_ef_dominance(tmp_path):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    src = os.path.join(_REPO, "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [_REPO, src, env.get("PYTHONPATH", "")])
    env["REPRO_BENCH_DIR"] = str(tmp_path)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "bench_recovery"],
        env=env, cwd=_REPO, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr

    path = tmp_path / "BENCH_recovery.json"
    assert path.exists(), "run.py did not honor REPRO_BENCH_DIR"
    payload = json.loads(path.read_text())
    assert payload["_meta"] == {"mode": "quick", "bench": "bench_recovery"}

    keys = set(payload) - {"_meta"}
    cells = [f"recovery/{pat}_r{pct}" for pat in ("bernoulli", "burst")
             for pct in (1, 5)]
    for cell in cells:
        for mech in ("zero", "stale", "ef"):
            assert f"{cell}/{mech}_mse_median" in keys, (cell, mech)
            assert f"{cell}/{mech}_mse_iqr" in keys, (cell, mech)

    # the acceptance claim: EF strictly dominates zero-fill at every rate
    # — including >= 1% burst loss — because carried residuals re-apply the
    # dropped mass instead of letting the error random-walk
    for cell in cells:
        zero = payload[f"{cell}/zero_mse_median"]["value"]
        ef = payload[f"{cell}/ef_mse_median"]["value"]
        assert ef < zero, (cell, ef, zero)

    # the checked-in baseline at the repo root was NOT rewritten
    repo_json = os.path.join(_REPO, "BENCH_recovery.json")
    if os.path.exists(repo_json):
        with open(repo_json) as fh:
            baseline = json.load(fh)
        assert baseline["_meta"]["bench"] == "bench_recovery"
