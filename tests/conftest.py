"""Test configuration. NOTE: no XLA device-count flags here by design —
smoke tests run on the single real device; collective-equivalence tests
spawn a subprocess with their own XLA_FLAGS (test_collectives.py)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    from hypothesis import settings
except ImportError:       # hypothesis absent: profile registration is
    settings = None       # best-effort; tests fall back to _hypothesis_fallback

if settings is not None:
    settings.register_profile("ci", max_examples=20, deadline=None)
    settings.load_profile("ci")
