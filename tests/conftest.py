"""Test configuration. NOTE: no XLA device-count flags here by design —
smoke tests run on the single real device; collective-equivalence tests
spawn a subprocess with their own XLA_FLAGS (test_collectives.py)."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_collection_modifyitems(config, items):
    """tpu-marked tests only make sense with a Mosaic backend: auto-skip
    elsewhere (the jax import is deferred until a marked test exists)."""
    marked = [it for it in items if it.get_closest_marker("tpu")]
    if not marked:
        return
    import jax
    if jax.default_backend() == "tpu":
        return
    skip = pytest.mark.skip(reason='jax.default_backend() != "tpu"')
    for it in marked:
        it.add_marker(skip)

try:
    from hypothesis import settings
except ImportError:       # hypothesis absent: profile registration is
    settings = None       # best-effort; tests fall back to _hypothesis_fallback

if settings is not None:
    settings.register_profile("ci", max_examples=20, deadline=None)
    settings.load_profile("ci")
