"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The property tests only use a tiny slice of the hypothesis API
(``@given`` with ``st.integers`` / ``st.floats`` / ``st.sampled_from``),
so when the real package is missing we degrade to running each property
over a small fixed set of representative examples (endpoints + midpoint)
instead of randomized search. Import pattern in test modules:

    try:
        from hypothesis import given, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, strategies as st
"""
from __future__ import annotations

import itertools


class _Strategy:
    def __init__(self, examples):
        self.examples = list(examples)

    def filter(self, predicate):
        kept = [e for e in self.examples if predicate(e)]
        if not kept:
            raise ValueError("fallback filter() left no examples — widen "
                             "the strategy's range")
        return _Strategy(kept)


class strategies:  # noqa: N801 — mirrors the ``hypothesis.strategies`` module
    @staticmethod
    def integers(min_value, max_value):
        mid = (min_value + max_value) // 2
        return _Strategy(dict.fromkeys([min_value, mid, max_value]))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy([min_value, (min_value + max_value) / 2.0,
                          max_value])

    @staticmethod
    def sampled_from(elements):
        return _Strategy(elements)


def given(*strats):
    """Run the property over the cartesian product of example values
    (capped to keep CI time bounded)."""
    def deco(fn):
        def wrapper():
            combos = itertools.product(*(s.examples for s in strats))
            for combo in itertools.islice(combos, 9):
                fn(*combo)
        # no functools.wraps: pytest must see a zero-arg signature, not the
        # wrapped property's parameters (it would look for fixtures)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


class settings:  # noqa: N801 — API-compatible no-op
    def __init__(self, *a, **kw):
        pass

    def __call__(self, fn):  # decorator form: @settings(...) over a @given
        return fn

    @staticmethod
    def register_profile(name, **kw):
        pass

    @staticmethod
    def load_profile(name):
        pass
