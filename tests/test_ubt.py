"""UBT controllers vs the paper's §3.2 update rules."""
import numpy as np
import pytest

from repro.core.ubt import AdaptiveTimeout, DynamicIncast, TimelyRateControl


class TestAdaptiveTimeout:
    def test_warmup_p95(self):
        at = AdaptiveTimeout(warmup_iters=20)
        for t in range(1, 21):
            at.observe_warmup(float(t))
        assert at.ready
        assert at.t_b == pytest.approx(np.percentile(range(1, 21), 95))

    def test_deadline_uses_tc_when_last_pctile_seen(self):
        at = AdaptiveTimeout(warmup_iters=2)
        at.observe_warmup(10.0)
        at.observe_warmup(10.0)
        assert at.round_deadline(last_pctile_seen=False) == at.t_b
        assert at.round_deadline(True) == pytest.approx(
            min(at.t_b, 1.1 * at.t_c))

    def test_x_doubles_on_high_loss_and_caps(self):
        at = AdaptiveTimeout(warmup_iters=1)
        at.observe_warmup(10.0)
        for _ in range(10):
            at.update(stage_times=[5.0], timed_out=[False],
                      frac_received=[1.0], loss_frac=0.01)  # > 0.1%
        assert at.x == pytest.approx(0.50)                  # capped at 50%

    def test_x_decrements_on_low_loss(self):
        at = AdaptiveTimeout(warmup_iters=1, x_init=0.10)
        at.observe_warmup(10.0)
        at.x = 0.10
        at.update(stage_times=[5.0], timed_out=[False],
                  frac_received=[1.0], loss_frac=0.0)       # < 0.01%
        assert at.x == pytest.approx(0.09)

    def test_tc_sources(self):
        """(1) on-time -> observed, (2) timeout -> t_B, (3) partial ->
        extrapolated; median across nodes then EMA with alpha=0.95."""
        at = AdaptiveTimeout(warmup_iters=1, alpha=0.95)
        at.observe_warmup(10.0)
        t_c0 = at.t_c
        at.update(stage_times=[4.0, 6.0, 5.0],
                  timed_out=[False, True, False],
                  frac_received=[1.0, 0.5, 0.5], loss_frac=5e-4)
        # samples: 4.0 (on time), t_b=10.0 (timeout), 5.0/0.5=10.0 (extrap)
        expected = 0.95 * np.median([4.0, 10.0, 10.0]) + 0.05 * t_c0
        assert at.t_c == pytest.approx(expected)

    def test_hadamard_activation_threshold(self):
        at = AdaptiveTimeout()
        assert at.hadamard_active(0.03)      # > 2%
        assert not at.hadamard_active(0.01)


class TestDynamicIncast:
    def test_grows_on_clean_rounds(self):
        di = DynamicIncast(n_nodes=8, i_init=1)
        for _ in range(10):
            di.update(loss_frac=0.0, timed_out=False)
        assert di.value == 7                  # capped at N-1

    def test_halves_on_loss(self):
        di = DynamicIncast(n_nodes=8, i_init=4)
        di.update(loss_frac=0.01, timed_out=False)
        assert di.value == 2
        di.update(loss_frac=0.0, timed_out=True)
        assert di.value == 1
        di.update(loss_frac=0.01, timed_out=True)
        assert di.value == 1                  # floor

    def test_senders_take_min(self):
        assert DynamicIncast.effective([4, 2, 7]) == 2


class TestTimely:
    def test_additive_increase(self):
        rc = TimelyRateControl(rate=1e9)
        rc.update(10e-6)                      # below T_low
        assert rc.rate == pytest.approx(1e9 + 50e6)

    def test_multiplicative_decrease(self):
        rc = TimelyRateControl(rate=10e9)
        r = rc.update(500e-6)                 # above T_high
        assert r == pytest.approx(10e9 * (1 - 0.5 * (1 - 250e-6 / 500e-6)))

    def test_hold_in_band(self):
        rc = TimelyRateControl(rate=5e9)
        assert rc.update(100e-6) == pytest.approx(5e9)
