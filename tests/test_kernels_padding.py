"""Padding-edge property tests for every Pallas kernel package.

Each kernel pads its streaming axis up to a whole number of grid blocks
(rows to ``block_rows``, columns to ``tile``) and slices the pad back off.
Because every output row/column depends only on its own input row/column,
the padded tail block must not perturb the kept prefix: for any prefix
length r — including r % block != 0, the tail-block path, and the
``step`` pad's ``constant_values=1.0`` guard — the kernel applied to the
prefix must equal the prefix of the kernel applied to the full operand,
*bit-exactly*, in interpret mode and (when a TPU backend is present)
Mosaic-compiled mode.
"""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, strategies as st
except ImportError:
    from _hypothesis_fallback import given, strategies as st

from repro.kernels import runtime
from repro.kernels.dequant_reduce.dequant_reduce import \
    dequant_masked_mean_pallas
from repro.kernels.fwht.fwht import fwht_pallas
from repro.kernels.ht_quant.ht_quant import ht_amax_pallas, ht_quant_pallas
from repro.kernels.masked_sum.masked_sum import masked_mean_pallas
from repro.kernels.quant.quant import grid_quant_pallas, uniform_quant_pallas

# compiled mode rides along automatically when this suite runs on a TPU box
MODES = ["interpret"] + (
    ["compile"] if jax.default_backend() == "tpu" else [])

N = 128          # Hadamard block / column width
BR = 8           # block_rows: small so tails are cheap to sweep
R = 3 * BR       # full row count (a whole number of blocks: no pad)

rows_st = st.integers(min_value=1, max_value=R)
seed_st = st.integers(min_value=0, max_value=2**31 - 1)


def _rows_data(seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (R, N), jnp.float32)
    sign = jnp.where(
        jax.random.bernoulli(jax.random.fold_in(key, 1), shape=(N,)),
        1.0, -1.0).astype(jnp.float32)
    noise = jax.random.uniform(jax.random.fold_in(key, 2), (R, N))
    amax = jnp.max(jnp.abs(x), axis=1) + 0.1
    lo = -amax
    step = 2.0 * amax / 255.0
    return x, sign, noise, lo, step


def _assert_prefix(run_full, run_prefix):
    for mode in MODES:
        with runtime.kernel_mode_scope(mode):
            full = np.asarray(run_full())
            prefix = np.asarray(run_prefix())
        np.testing.assert_array_equal(prefix, full[:prefix.shape[0]])


@given(rows_st, seed_st)
def test_fwht_prefix_invariant(r, seed):
    x, _, _, _, _ = _rows_data(seed)
    _assert_prefix(lambda: fwht_pallas(x, block_rows=BR),
                   lambda: fwht_pallas(x[:r], block_rows=BR))


@given(rows_st, seed_st)
def test_ht_amax_prefix_invariant(r, seed):
    x, sign, _, _, _ = _rows_data(seed)
    _assert_prefix(lambda: ht_amax_pallas(x, sign, block_rows=BR),
                   lambda: ht_amax_pallas(x[:r], sign, block_rows=BR))


@given(rows_st, seed_st)
def test_ht_quant_prefix_invariant(r, seed):
    # the tail block runs the step pad's constant_values=1.0 guard: a zero
    # pad would 0-divide inside the kernel
    x, sign, noise, lo, step = _rows_data(seed)
    _assert_prefix(
        lambda: ht_quant_pallas(x, sign, noise, lo, step, block_rows=BR),
        lambda: ht_quant_pallas(x[:r], sign, noise[:r], lo[:r], step[:r],
                                block_rows=BR))


@given(rows_st, seed_st)
def test_grid_quant_prefix_invariant(r, seed):
    x, _, noise, lo, step = _rows_data(seed)
    _assert_prefix(
        lambda: grid_quant_pallas(x, noise, lo, step, block_rows=BR),
        lambda: grid_quant_pallas(x[:r], noise[:r], lo[:r], step[:r],
                                  block_rows=BR))


@given(rows_st, seed_st)
def test_uniform_quant_prefix_invariant(r, seed):
    x, _, noise, _, _ = _rows_data(seed)
    lohi = jnp.array([-3.0, 3.0], jnp.float32)
    _assert_prefix(
        lambda: uniform_quant_pallas(x, noise, lohi, block_rows=BR),
        lambda: uniform_quant_pallas(x[:r], noise[:r], lohi, block_rows=BR))


# ---- column-streamed kernels: the pad is on the length axis ---------------
TILE = 64
L = 3 * TILE

cols_st = st.integers(min_value=1, max_value=L)


def _cols_data(seed):
    key = jax.random.PRNGKey(seed)
    shards = jax.random.normal(key, (4, L), jnp.float32)
    mask = jax.random.bernoulli(jax.random.fold_in(key, 1),
                                0.8, (4, L)).astype(jnp.float32)
    codes = jax.random.randint(jax.random.fold_in(key, 2), (4, L),
                               0, 256, jnp.int32).astype(jnp.uint8)
    lo_row = jax.random.normal(jax.random.fold_in(key, 3), (L,))
    step_row = jax.random.uniform(jax.random.fold_in(key, 4), (L,),
                                  minval=0.01, maxval=0.1)
    return shards, mask, codes, lo_row, step_row


@given(cols_st, seed_st)
def test_masked_mean_prefix_invariant(c, seed):
    shards, mask, _, _, _ = _cols_data(seed)
    _assert_prefix(
        lambda: masked_mean_pallas(shards, mask, tile=TILE),
        lambda: masked_mean_pallas(shards[:, :c], mask[:, :c], tile=TILE))


@given(cols_st, seed_st)
def test_dequant_masked_mean_prefix_invariant(c, seed):
    _, mask, codes, lo_row, step_row = _cols_data(seed)
    for m, mp in [(mask, lambda: mask[:, :c]), (None, lambda: None)]:
        _assert_prefix(
            lambda: dequant_masked_mean_pallas(codes, lo_row, step_row, m,
                                               tile=TILE),
            lambda: dequant_masked_mean_pallas(codes[:, :c], lo_row[:c],
                                               step_row[:c], mp(),
                                               tile=TILE))
