"""Host wire transport end-to-end: inproc determinism/fidelity, adaptive
deadlines, ControlPlane integration, and the UDP backend (which auto-skips
when the sandbox forbids socket binding, and runs a real 4-peer localhost
allreduce as a slow smoke).
"""
import os
import subprocess
import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import drops as drops_lib
from repro.core import tar as tar_lib
from repro.core.allreduce import OptiReduceConfig
from repro.core.pipeline import resolve_spec
from repro.core.ubt import AdaptiveTimeout
from repro.net import (HostRing, InprocBackend, bernoulli_drops,
                       mask_scripted_drops, peer_factor_delays, udp_available)
from repro.runtime import ControlPlane

pytestmark = pytest.mark.net

N = 4
KEY = jax.random.PRNGKey(5)


def _cfg(**kw):
    base = dict(strategy="optireduce", drop_rate=0.0, hadamard_block=256,
                packet_elems=64)
    base.update(kw)
    return OptiReduceConfig(**base)


def _buckets(elems=1000, seed=1):
    return np.random.default_rng(seed).standard_normal(
        (N, elems)).astype(np.float32)


def test_inproc_no_drop_allreduce_is_the_mean_and_deterministic():
    cfg = _cfg()
    buckets = _buckets()
    out1, tel1 = HostRing(N, cfg, backend="inproc").allreduce(buckets, KEY)
    out2, tel2 = HostRing(N, cfg, backend="inproc").allreduce(buckets, KEY)
    np.testing.assert_array_equal(out1, out2)        # fully deterministic
    assert tel1.loss_frac == 0.0 and not tel1.timed_out
    true = buckets.mean(axis=0)
    for p in range(N):
        np.testing.assert_allclose(out1[p], true, atol=1e-5)
    # every peer decodes identical bytes (stage 2 is authoritative)
    for p in range(1, N):
        np.testing.assert_array_equal(out1[0], out1[p])
    # telemetry fully populated: one round entry per exchange round per
    # stage, one stage-time entry per peer
    assert len(tel1.peer_stage_times) == N
    assert all(t == t for t in tel1.peer_stage_times)    # no NaNs: all seen
    assert len(tel1.round_times) == 2 * (N - 1)          # stage 1 + stage 2
    assert tel1.round_frac_received == (1.0,) * (2 * (N - 1))


def test_scripted_drops_produce_the_exact_drops_masks():
    """The wire-observed mask at each receiver is bitwise the core/drops.py
    mask the script was derived from (the parity mechanism, single
    process)."""
    cfg = _cfg(drop_rate=0.1)
    spec = resolve_spec(cfg)
    padded, _ = tar_lib.pad_for_tar(jnp.zeros(1000), N,
                                    spec.codec.block(cfg))
    s = padded.shape[0] // N
    masks = {me: np.asarray(drops_lib.make_mask(
        cfg.drop_pattern, jax.random.fold_in(KEY, me), N, s,
        rate=cfg.drop_rate, packet_elems=cfg.packet_elems,
        self_index=jnp.asarray(me))) for me in range(N)}
    ring = HostRing(N, _cfg(), backend=InprocBackend(
        N, drop_fn=mask_scripted_drops(masks, cfg.packet_elems)))
    shards = {me: np.arange(N * s, dtype=np.float32).reshape(N, s) + me
              for me in range(N)}
    got: dict = {}

    def call_round(tag):
        def call(me):
            got[(tag, me)] = ring.bridge_exchange(me, shards[me])
        threads = [threading.Thread(target=call, args=(me,))
                   for me in range(N)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)

    call_round(0)                    # priming: masks are all-ones
    assert ring.flush()
    call_round(1)                    # consumes exchange 0's observed masks
    for me in range(N):
        np.testing.assert_array_equal(got[(0, me)],
                                      np.ones((N, s), np.float32))
        np.testing.assert_array_equal(got[(1, me)], masks[me])
    assert ring.bridge_misses == 0
    assert ring.flush()
    tel = ring.drain_telemetry()
    want_loss = 1.0 - np.mean([masks[me] for me in range(N)])
    assert tel.loss_frac == pytest.approx(want_loss, abs=1e-7)


def test_bernoulli_wire_loss_tracks_the_scripted_rate():
    ring = HostRing(N, _cfg(), backend="inproc",
                    drop_fn=bernoulli_drops(0.05, seed=3))
    dropped = total = 0.0
    for step in range(8):
        _, tel = ring.allreduce(_buckets(4096), KEY, step=step)
        dropped += tel.dropped
        total += tel.total
    assert 0.01 < dropped / total < 0.12


def test_late_packets_are_masked_never_blocked():
    """A peer slower than the receive deadline is equivalent to loss: its
    entries are masked, the round flags a timeout, and the result is still
    the compensated mean over the peers that made it."""
    slow = 2
    factors = tuple(50.0 if p == slow else 1.0 for p in range(N))
    ring = HostRing(N, _cfg(), backend=InprocBackend(
        N, delay_fn=peer_factor_delays(1e-4, factors)),
        default_deadline=1e-3)           # 50x base delay > deadline
    buckets = _buckets()
    out, tel = ring.allreduce(buckets, KEY)
    assert tel.timed_out
    assert tel.loss_frac > 0.0
    # the slow peer was charged the deadline (the straggler signal)
    assert tel.peer_stage_times[slow] == pytest.approx(1e-3)
    assert max(tel.peer_stage_times[p] for p in range(N) if p != slow) \
        < 1e-3
    # the exact degraded semantics: every receiver's aggregation excluded
    # the slow peer's stage-1 contributions (compensated mean over the 3
    # on-time peers — hadamard_block 256 == shard size, so regions align),
    # and the slow peer's own stage-2 shard region is a zero-filled hole
    # (stage-2 loss is a real gap; DESIGN §2/§7)
    s = 256                               # padded 1024 over 4 peers
    mean3 = buckets[[p for p in range(N) if p != slow]].mean(axis=0)
    np.testing.assert_allclose(out[0][:slow * s], mean3[:slow * s],
                               atol=1e-5)
    np.testing.assert_allclose(out[0][(slow + 1) * s:],
                               mean3[(slow + 1) * s:], atol=1e-5)
    np.testing.assert_array_equal(out[0][slow * s:(slow + 1) * s],
                                  np.zeros(s, np.float32))


def test_adaptive_timeout_drives_the_deadline():
    """Once the AdaptiveTimeout is profiled, the receive loop's budget is
    its round_deadline; before that, the configured default."""
    at = AdaptiveTimeout(warmup_iters=3)
    ring = HostRing(N, _cfg(), backend="inproc", timeout=at,
                    default_deadline=7.0)
    assert ring.peers[0].round_deadline() == 7.0
    for t in (0.1, 0.2, 0.3):
        at.observe_warmup(t)
    assert at.ready
    assert ring.peers[0].round_deadline() == at.round_deadline(False)
    assert ring.peers[0].round_deadline() < 7.0


def test_early_timeout_shaves_the_straggling_tail():
    """§3.2.1 engaged on the wire: once 99% of a stream's packets are in,
    the receiver waits only x%*t_C more — a single packet straggling far
    behind (a stalled flow's retransmit tail) is masked at ~t99 + x*t_C
    instead of burning the hard t_B bound."""
    from repro.net.wire import KIND_DATA1, n_packets

    elems, pe = 4096, 64
    s = elems // N                       # 1024 elems -> 16 packets/stream
    n_pkts = n_packets(s, pe)
    tail_seq = n_pkts - 1

    def delay(src, dst, hdr):
        if hdr.kind == KIND_DATA1 and hdr.seq == tail_seq:
            return 0.5                   # one packet stalls far behind
        return 1e-4

    at = AdaptiveTimeout()
    at.t_b, at.t_c, at.x = 1.0, 1e-3, 0.1
    ring = HostRing(N, _cfg(), backend=InprocBackend(N, delay_fn=delay),
                    timeout=at)
    out, tel = ring.allreduce(_buckets(elems), KEY)
    # stage-1 rounds expired early: charged ~1e-4 + 0.1*1e-3, not 0.5/1.0
    stage1 = tel.round_times[:N - 1]
    assert all(t < 5e-4 for t in stage1), stage1
    assert tel.timed_out
    # exactly the tail packet of each stage-1 stream is masked
    per_stream = 1.0 - (n_pkts - 1) / n_pkts
    want = per_stream * (N - 1) / N      # self rows never drop
    assert tel.loss_frac == pytest.approx(want, abs=1e-6)


def test_wire_telemetry_feeds_straggler_detection():
    """The closed loop the ROADMAP asked for: wire-observed per-peer stage
    times flow through StepTelemetry into the ControlPlane, whose detector
    ejects the persistent straggler."""
    slow = 1
    factors = tuple(6.0 if p == slow else 1.0 for p in range(N))
    ring = HostRing(N, _cfg(), backend=InprocBackend(
        N, delay_fn=peer_factor_delays(1e-4, factors)))
    control = ControlPlane.create(n_nodes=N)
    buckets = _buckets(512)
    for step in range(12):
        _, tel = ring.allreduce(buckets, KEY, step=step)
        assert tel.peer_stage_times is not None
        assert len(tel.peer_stage_times) == N
        control.observe(tel)
    policy = control.policy()
    assert policy.active_peers is not None
    assert slow not in policy.active_peers
    assert control.detector.ejected_peers() == (slow,)


def test_quantized_strategy_over_the_wire():
    """HTQuant codes cross the wire as uint8; the amax grids max-share over
    the control channel, so all peers decode identical bytes and the
    dequantized mean lands near the true mean."""
    cfg = _cfg(strategy="optireduce_q", quant_bits=8)
    buckets = _buckets(2048)
    out, tel = HostRing(N, cfg, backend="inproc").allreduce(buckets, KEY)
    for p in range(1, N):
        np.testing.assert_array_equal(out[0], out[p])
    true = buckets.mean(axis=0)
    scale = np.abs(buckets).max()
    assert np.abs(out[0] - true).max() < 0.05 * scale
    assert tel.loss_frac == 0.0


def test_non_tar_strategy_rejected():
    with pytest.raises(ValueError, match="TAR"):
        HostRing(N, _cfg(strategy="gloo_ring"), backend="inproc")


# ----------------------------------------------------------- the launcher
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_launcher_transport_inproc_feeds_peer_stage_times(tmp_path):
    """Acceptance pin: ``launch/train.py --transport=inproc`` produces
    StepTelemetry.peer_stage_times — one entry per peer, consumed by the
    ControlPlane/StragglerDetector — closing the ROADMAP item that the
    launcher only ever fed step wall-clock."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_REPO, "src"), env.get("PYTHONPATH", "")])
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--smoke",
         "--steps", "3", "--dp", "4", "--tp", "1",
         "--strategy", "optireduce", "--transport", "inproc",
         "--drop-rate", "0.02", "--log-every", "1",
         "--global-batch", "8", "--seq-len", "64"],
        env=env, cwd=_REPO, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    wire_lines = [l for l in proc.stdout.splitlines()
                  if l.startswith("wire[inproc]")]
    assert wire_lines, proc.stdout
    # one stage-time entry per peer, all populated
    assert "peers=4" in wire_lines[0]
    times = wire_lines[0].split("stage_times=[")[1].split("]")[0].split(",")
    assert len(times) == 4
    assert all(float(t) > 0 for t in times)
    # the wire really injected loss and the steps observed it
    losses = [float(l.split("loss_frac=")[1].split()[0]) for l in wire_lines]
    assert max(losses) > 0.0, wire_lines
    assert "done" in proc.stdout


# --------------------------------------------------------------------- UDP
needs_udp = pytest.mark.skipif(
    not udp_available(),
    reason="sandbox forbids UDP socket binding on localhost")


@needs_udp
def test_udp_two_peer_allreduce_quick():
    ring = HostRing(2, _cfg(), backend="udp", default_deadline=2.0)
    try:
        buckets = np.random.default_rng(0).standard_normal(
            (2, 600)).astype(np.float32)
        out, tel = ring.allreduce(buckets, KEY)
        np.testing.assert_allclose(out[0], buckets.mean(axis=0), atol=1e-5)
        np.testing.assert_array_equal(out[0], out[1])
        assert len(tel.peer_stage_times) == 2
    finally:
        ring.close()


@needs_udp
@pytest.mark.slow
def test_udp_four_peer_allreduce_end_to_end():
    """The real thing: 4 peers, real localhost sockets, injected loss, the
    adaptive timeout warming up from observed stage times — repeated steps
    so reassembly handles genuine kernel-scheduling reorder."""
    at = AdaptiveTimeout(warmup_iters=5)
    control = ControlPlane.create(n_nodes=4)
    control.state.timeout = at
    ring = HostRing(4, _cfg(), backend="udp", timeout=at,
                    default_deadline=2.0,
                    drop_fn=bernoulli_drops(0.02, seed=7))
    try:
        buckets = _buckets(4096)
        true = buckets.mean(axis=0)
        losses = []
        for step in range(8):
            out, tel = ring.allreduce(buckets, KEY, step=step)
            control.observe(tel)
            losses.append(tel.loss_frac)
            # sanity at every peer under injected loss + real-clock timing
            # (a loaded box can expire whole rounds): values stay finite
            # and bounded by the contributions — a zeroed span reads 0, a
            # compensated span is a mean over a subset of the buckets
            bound = np.abs(buckets).max() + 1e-5
            for p in range(4):
                assert np.isfinite(out[p]).all()
                assert np.abs(out[p] - true).max() <= bound
        assert any(l > 0 for l in losses)          # loss really injected
        assert at.ready                            # warmup profiled from wire
        assert ring.peers[0].round_deadline() <= 2.0
    finally:
        ring.close()


def test_burst_wire_drops_are_bursty_order_free_and_on_rate():
    """The Gilbert–Elliott wire drop schedule (DESIGN §8): header-pure
    (out-of-order replay gives identical answers), statistically on-rate,
    and with multi-packet loss runs along seq — the same chain the in-JAX
    burst masks use."""
    from repro.net import burst_drops
    from repro.net.wire import KIND_CTRL, KIND_DATA1, PacketHeader

    def hdr(seq, step=0):
        return PacketHeader(kind=KIND_DATA1, sender=0, step=step, bucket=0,
                            round=1, seq=seq, n_seq=4096)

    fn = burst_drops(0.1, seed=2)
    n_seq, streams = 4096, 24
    verdicts = {}
    for s in range(streams):
        for q in range(n_seq):
            verdicts[(s, q)] = fn(0, 1, hdr(q, step=s))
    # order-free: a fresh schedule queried in reverse agrees everywhere
    fn2 = burst_drops(0.1, seed=2)
    for s in reversed(range(streams)):
        for q in reversed(range(n_seq)):
            assert fn2(0, 1, hdr(q, step=s)) == verdicts[(s, q)]

    lost = np.array([[verdicts[(s, q)] for q in range(n_seq)]
                     for s in range(streams)], dtype=int)
    rate = lost.mean()
    assert 0.05 < rate < 0.15            # stationary loss tracks the rate
    runs = []
    for row in lost:
        edges = np.flatnonzero(np.diff(np.concatenate([[0], row, [0]])))
        runs.extend((edges[1::2] - edges[::2]).tolist())
    from repro.core.drops import BURST_MEAN_PKTS
    assert BURST_MEAN_PKTS * 0.6 < float(np.mean(runs)) < BURST_MEAN_PKTS * 1.4

    # CTRL packets are never dropped (drop scripts touch DATA only)
    ctrl = PacketHeader(kind=KIND_CTRL, sender=0, step=0, bucket=0,
                        round=1, seq=0, n_seq=1)
    assert not fn(0, 1, ctrl)


# ------------------------------------------------- ISSUE 8: link rewiring
def test_dead_link_relay_completes_bitwise_without_ejection():
    """A scripted dead directed edge: with ``dead_links`` configured the
    step completes through a two-hop relay — bitwise-identical to the
    fault-free baseline, zero observed loss, neither endpoint ejected."""
    from repro.net import KIND_DATA1, KIND_DATA2

    buckets = _buckets(2048)
    base, _ = HostRing(N, _cfg(), backend="inproc").allreduce(buckets, KEY)

    def kill(src, dst, hdr):
        return src == 2 and dst == 0 and hdr.kind in (KIND_DATA1, KIND_DATA2)

    ring = HostRing(N, _cfg(), backend="inproc", drop_fn=kill,
                    dead_links=((2, 0),))
    out, tel = ring.allreduce(buckets, KEY)
    np.testing.assert_array_equal(out, base)
    assert tel.loss_frac == 0.0
    # relayed traffic never crosses the dead physical edge, so the edge
    # is not re-reported as a fault (it is already being routed around)
    assert tel.dead_link_events == ()


def test_link_fault_detected_then_rerouted_closed_loop():
    """The full loop: an *untold* link fault shows up as a dead_link_event,
    the ControlPlane's patience turns it into SyncPolicy.dead_links, and a
    ring rebuilt under that policy completes the step bitwise-clean —
    without ejecting either endpoint."""
    from repro.net import KIND_DATA1, KIND_DATA2

    buckets = _buckets(2048)
    base, _ = HostRing(N, _cfg(), backend="inproc").allreduce(buckets, KEY)

    def kill(src, dst, hdr):
        return src == 2 and dst == 0 and hdr.kind in (KIND_DATA1, KIND_DATA2)

    control = ControlPlane.create(n_nodes=N, link_patience=2)
    faulty = HostRing(N, _cfg(), backend="inproc", drop_fn=kill)
    for step in range(2):
        _, tel = faulty.allreduce(buckets, KEY, step=step)
        assert (2, 0) in tel.dead_link_events      # receiver 0 flags src 2
        assert tel.loss_frac > 0.0                 # the fault really bit
        control.observe(tel)
    dead = control.policy().dead_links
    assert dead == ((2, 0),)
    # recompile under the policy: same fault, now rerouted
    healed = HostRing(N, _cfg(), backend="inproc", drop_fn=kill,
                      dead_links=dead)
    out, tel = healed.allreduce(buckets, KEY, step=2)
    np.testing.assert_array_equal(out, base)
    assert tel.loss_frac == 0.0 and tel.dead_link_events == ()
    # the point of rewiring: both endpoints stay in the job
    assert control.detector.ejected_peers() == ()


# ---------------------------------------------- ISSUE 8: weighted shards
def test_weighted_wire_bitwise_matches_uniform():
    """Straggler-proportional shard weights over the wire: same bytes, a
    different ownership split — bitwise-identical to the uniform exchange
    at zero drops (the same masked-mean row-order argument as in-JAX)."""
    buckets = _buckets(2048)
    base, _ = HostRing(N, _cfg(), backend="inproc").allreduce(buckets, KEY)
    ring = HostRing(N, _cfg(), backend="inproc", shard_weights=(2, 2, 1, 2))
    out, tel = ring.allreduce(buckets, KEY)
    np.testing.assert_array_equal(out, base)
    assert tel.loss_frac == 0.0
    # a uniform tuple normalizes away entirely (the parity discipline:
    # full weight everywhere is *the same policy* as no weights)
    uniform = HostRing(N, _cfg(), backend="inproc",
                       shard_weights=(3, 3, 3, 3))
    assert all(p.shard_weights is None for p in uniform.peers)


def test_weighted_wire_with_dead_link_still_bitwise():
    from repro.net import KIND_DATA1, KIND_DATA2

    buckets = _buckets(2048)
    base, _ = HostRing(N, _cfg(), backend="inproc").allreduce(buckets, KEY)

    def kill(src, dst, hdr):
        return src == 1 and dst == 3 and hdr.kind in (KIND_DATA1, KIND_DATA2)

    ring = HostRing(N, _cfg(), backend="inproc", drop_fn=kill,
                    shard_weights=(2, 1, 2, 2), dead_links=((1, 3),))
    out, tel = ring.allreduce(buckets, KEY)
    np.testing.assert_array_equal(out, base)
    assert tel.loss_frac == 0.0


def test_weighted_wire_rejects_quantized_codec():
    # HTQuant grids are keyed on uniform shard geometry — must refuse
    with pytest.raises(ValueError, match="linear"):
        HostRing(N, _cfg(strategy="optireduce_q"), backend="inproc",
                 shard_weights=(2, 2, 1, 2))
