"""Compression baselines: Top-K error feedback, TernGrad unbiasedness,
THC homomorphic roundtrip."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, strategies as st
except ImportError:
    from _hypothesis_fallback import given, strategies as st

from repro.core.compression import (THCCompressed, terngrad_compress,
                                    thc_compress, thc_decompress_sum,
                                    topk_compress, topk_init)


def test_topk_keeps_largest_and_feeds_back():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 0.0])
    state = topk_init(6)
    sparse, state = topk_compress(x, state, k=2)
    nz = np.nonzero(np.asarray(sparse))[0]
    assert set(nz) == {1, 3}
    np.testing.assert_allclose(np.asarray(state.error),
                               np.asarray(x - sparse), atol=1e-7)


def test_topk_error_feedback_recovers_mass():
    """Entries skipped now are sent later: cumulative transmitted -> x*T
    up to the O(1/T) residual still sitting in the feedback memory."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256,))
    state = topk_init(256)
    sent = jnp.zeros_like(x)
    T = 50
    for _ in range(T):
        s, state = topk_compress(x, state, k=16)
        sent = sent + s
    est = np.asarray(sent / T)
    ref = np.asarray(x)
    rel_l2 = np.linalg.norm(est - ref) / np.linalg.norm(ref)
    assert rel_l2 < 0.25, rel_l2
    # exact mass conservation: sent + residual error == T * x
    total = np.asarray(sent + state.error)
    np.testing.assert_allclose(total, T * ref, rtol=1e-3, atol=1e-3)


@given(st.integers(0, 2**31 - 1))
def test_terngrad_unbiased(seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (64,)) * 0.3
    trials = 300
    keys = jax.random.split(jax.random.fold_in(key, 1), trials)
    outs = jax.vmap(lambda k: terngrad_compress(x, k))(keys)
    mean = jnp.mean(outs, 0)
    assert float(jnp.max(jnp.abs(mean - x))) < 0.25


def test_terngrad_values_ternary():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (512,))
    out = np.asarray(terngrad_compress(x, key))
    s = float(jnp.max(jnp.abs(x)))
    uniq = np.unique(np.round(np.abs(out[out != 0]) / s, 5))
    assert len(uniq) <= 1


def test_thc_roundtrip_error_bound():
    key = jax.random.PRNGKey(2)
    n, block = 4, 1024
    # data key must differ from the transform key: deriving both from one
    # key correlates the Rademacher signs with the values, which piles the
    # whole bucket into the DC coefficient and clips it (found the hard way)
    xs = jax.random.normal(jax.random.PRNGKey(99), (n, block))
    lohi = jnp.array([-8.0, 8.0])
    codes = []
    for i in range(n):
        c = thc_compress(xs[i], key, lohi, bits=8, block=block)
        assert isinstance(c, THCCompressed)
        codes.append(c.codes.astype(jnp.int32))
    out = thc_decompress_sum(sum(codes), key, lohi, bits=8, block=block,
                             nsum=n)
    step = 16.0 / 255
    # the rotation spreads per-coordinate quantization noise: bound the RMS
    # (max-norm can concentrate up to ||e||_2 after the inverse transform)
    rms = float(jnp.sqrt(jnp.mean((out - jnp.mean(xs, 0)) ** 2)))
    assert rms < step, rms
