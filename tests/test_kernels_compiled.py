"""Mosaic-compiled kernel checks — run only on a real TPU backend.

CPU CI exercises every kernel in the Pallas interpreter; these tests close
the remaining gap on real hardware: the compiled double-buffered kernels
must agree with their interpreted selves (same grid, same revolving-buffer
DMA schedule, Mosaic lowering instead of the interpreter), and ``auto``
dispatch must actually pick compilation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import runtime
from repro.kernels.dequant_reduce.dequant_reduce import \
    dequant_masked_mean_pallas
from repro.kernels.fwht.fwht import fwht_pallas
from repro.kernels.ht_quant.ht_quant import ht_amax_pallas, ht_quant_pallas
from repro.kernels.masked_sum.masked_sum import masked_mean_pallas
from repro.kernels.quant.quant import grid_quant_pallas, uniform_quant_pallas

pytestmark = pytest.mark.tpu


def _both_modes(fn):
    with runtime.kernel_mode_scope("interpret"):
        interp = np.asarray(fn())
    with runtime.kernel_mode_scope("compile"):
        comp = np.asarray(fn())
    return interp, comp


def test_auto_picks_compile_on_tpu():
    with runtime.kernel_mode_scope("auto"):
        assert runtime.resolve() == "compile"
        assert not runtime.interpret_flag()


@pytest.mark.parametrize("rows", [4, 37, 64])
def test_fwht_compiled_matches_interpret(rows):
    x = jax.random.normal(jax.random.PRNGKey(rows), (rows, 1024))
    interp, comp = _both_modes(lambda: fwht_pallas(x, block_rows=16))
    np.testing.assert_allclose(comp, interp, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("rows", [4, 37])
def test_ht_amax_compiled_matches_interpret(rows):
    key = jax.random.PRNGKey(rows)
    x = jax.random.normal(key, (rows, 1024))
    sign = jnp.where(jax.random.bernoulli(jax.random.fold_in(key, 1),
                                          shape=(1024,)), 1.0, -1.0)
    interp, comp = _both_modes(
        lambda: ht_amax_pallas(x, sign, block_rows=16))
    np.testing.assert_allclose(comp, interp, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("rows", [4, 37])
def test_ht_quant_compiled_matches_interpret(rows):
    key = jax.random.PRNGKey(rows)
    x = jax.random.normal(key, (rows, 1024))
    sign = jnp.where(jax.random.bernoulli(jax.random.fold_in(key, 1),
                                          shape=(1024,)), 1.0, -1.0)
    noise = jax.random.uniform(jax.random.fold_in(key, 2), x.shape)
    amax = jnp.max(jnp.abs(x), axis=1) * jnp.sqrt(1024.0)
    lo, step = -amax, 2.0 * amax / 255.0
    interp, comp = _both_modes(
        lambda: ht_quant_pallas(x, sign, noise, lo, step, block_rows=16))
    # codes are integers: any float divergence at a rounding boundary moves
    # a code by at most 1 level
    assert np.abs(comp.astype(np.int32) - interp.astype(np.int32)).max() <= 1


def test_quant_compiled_matches_interpret():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (37, 512))
    noise = jax.random.uniform(jax.random.fold_in(key, 1), x.shape)
    lohi = jnp.array([-3.0, 3.0])
    amax = jnp.max(jnp.abs(x), axis=1) + 0.1
    interp_u, comp_u = _both_modes(
        lambda: uniform_quant_pallas(x, noise, lohi, block_rows=16))
    assert np.abs(comp_u.astype(np.int32)
                  - interp_u.astype(np.int32)).max() <= 1
    interp_g, comp_g = _both_modes(
        lambda: grid_quant_pallas(x, noise, -amax, 2 * amax / 255,
                                  block_rows=16))
    assert np.abs(comp_g.astype(np.int32)
                  - interp_g.astype(np.int32)).max() <= 1


def test_reduce_kernels_compiled_match_interpret():
    key = jax.random.PRNGKey(3)
    shards = jax.random.normal(key, (8, 4096))
    mask = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.8,
                                shards.shape).astype(jnp.float32)
    interp_m, comp_m = _both_modes(
        lambda: masked_mean_pallas(shards, mask, tile=1024))
    np.testing.assert_allclose(comp_m, interp_m, rtol=1e-6, atol=1e-6)
    codes = jax.random.randint(jax.random.fold_in(key, 2), (8, 4096),
                               0, 256, jnp.int32).astype(jnp.uint8)
    lo = jax.random.normal(jax.random.fold_in(key, 3), (4096,))
    step = jax.random.uniform(jax.random.fold_in(key, 4), (4096,),
                              minval=0.01, maxval=0.1)
    interp_d, comp_d = _both_modes(
        lambda: dequant_masked_mean_pallas(codes, lo, step, mask, tile=1024))
    np.testing.assert_allclose(comp_d, interp_d, rtol=1e-6, atol=1e-6)
