"""§Perf hillclimb code paths: pure_dp remap, weights-stationary MoE,
quantized TAR / reduce-scatter wires. Multi-device equivalence runs in a
subprocess (same pattern as test_collectives.py)."""
import os
import subprocess
import sys

import pytest

CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, shard_map
from repro.core import OptiReduceConfig, SyncContext, sync_bucket
from repro.core.allreduce import reduce_scatter_axis
from repro.configs.base import ModelConfig
from repro.models import init_params, init_decode_state, decode_step, param_specs
from repro.models.parallel import ParallelCtx

key = jax.random.PRNGKey(0)

# 1) optireduce_q (quantized TAR): bounded error, replica-consistent
mesh = make_mesh((8,), ("data",))
xs = jax.random.normal(key, (8, 20000), jnp.float32)
expected = np.asarray(jnp.mean(xs, 0))
cfg = OptiReduceConfig(strategy="optireduce_q", drop_rate=0.0,
                       hadamard_block=1024, quant_bits=8)
def body(x):
    ctx = SyncContext(cfg=cfg, key=jax.random.PRNGKey(7))
    return sync_bucket(x.reshape(-1), ctx)[None]
f = jax.jit(shard_map(body, mesh=mesh, in_specs=P("data", None),
                          out_specs=P("data", None), check_vma=False))
out = np.asarray(f(xs))
rel = np.sqrt(np.mean((out[0]-expected)**2)) / np.std(expected)
assert rel < 0.10, rel
assert np.max(np.abs(out - out[0:1])) == 0.0
print("optireduce_q OK")

# 2) quantized reduce-scatter wire
g = jax.random.normal(key, (8, 64, 48))
cfg_rs = OptiReduceConfig(drop_rate=0.0, rs_wire_bits=8, hadamard_block=256)
def rs_body(x):
    ctx = SyncContext(cfg=cfg_rs, key=jax.random.PRNGKey(1))
    i = jax.lax.axis_index("data")
    return reduce_scatter_axis(jnp.take(x, i, 0), "data", 0, ctx,
                               with_drops=False)
fr = jax.jit(shard_map(rs_body, mesh=mesh, in_specs=P(None, None, None),
                           out_specs=P("data", None), check_vma=False))
rs_out = np.asarray(fr(g))
true = np.asarray(jnp.mean(g, 0))
rel = np.sqrt(np.mean((rs_out - true)**2)) / true.std()
assert rel < 0.10, rel
print("rs_wire_q8 OK")

# 3) weights-stationary MoE decode == gathered decode (exact)
mcfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=64, n_heads=4,
                   n_kv_heads=2, d_ff=96, vocab_size=128, n_experts=8,
                   top_k=2, param_dtype=jnp.float32)
mesh2 = make_mesh((4, 2), ("data", "model"))
params = init_params(key, mcfg)
tok = jax.random.randint(key, (8, 1), 0, 128)
def run(moe_stat):
    def gather(w, dim, k):
        del k
        return jax.lax.all_gather(w, "data", axis=dim, tiled=True)
    pctx = ParallelCtx(tp_axis="model", dp_axis="data", fsdp=True,
                       gather=gather, moe_stationary=moe_stat)
    p_specs = param_specs(mcfg, tp=2, fsdp_axes=("data",))
    state = init_decode_state(params, mcfg, batch=8, max_seq=4, tp=1,
                              dtype=jnp.float32)
    from repro.models.layers import KVCache
    st_specs = [KVCache(k=P(None, "data", None, "model", None),
                        v=P(None, "data", None, "model", None))]
    def b(p, st, t):
        return decode_step(p, st, t, jnp.int32(0), mcfg, pctx,
                           key=jax.random.PRNGKey(1))
    fj = jax.jit(shard_map(b, mesh=mesh2,
                 in_specs=(p_specs, st_specs, P("data", None)),
                 out_specs=(P("data", None), st_specs), check_vma=False))
    nxt, _ = fj(params, state, tok)
    return np.asarray(nxt)
assert np.array_equal(run(False), run(True))
print("moe_stationary OK")

# 4) pure_dp trainer remap: loss decreases, matches tp-trainer direction
from repro.optim.optimizers import OptimizerConfig
from repro.train.trainer import TrainConfig, build_train_step
tcfg = ModelConfig(name="t2", family="dense", n_layers=2, d_model=32,
                   n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                   param_dtype=jnp.float32)
batch = {"tokens": jax.random.randint(key, (8, 16), 0, 128),
         "labels": jax.random.randint(key, (8, 16), 0, 128)}
tc = TrainConfig(sync=OptiReduceConfig(strategy="optireduce", drop_rate=0.0,
                                       hadamard_block=256),
                 optimizer=OptimizerConfig(lr=1e-2),
                 dp_mode="replicated", seq_chunk=16, pure_dp=True)
make_step, opt, _ = build_train_step(tcfg, tc, mesh2)
params2 = init_params(key, tcfg)
step_fn, sh = make_step(jax.eval_shape(opt.init, params2), batch)
params2 = jax.device_put(params2, sh["params"])
opt_state = jax.jit(opt.init, out_shardings=sh["opt"])(params2)
b2 = jax.device_put(batch, sh["batch"])
jf = jax.jit(step_fn)
ls = []
for i in range(4):
    params2, opt_state, m = jf(params2, opt_state, b2,
                               jnp.asarray(i, jnp.int32), key)
    ls.append(float(m["loss"]))
assert ls[-1] < ls[0], ls
print("pure_dp OK")

# 5) sequence parallelism: first-step loss matches the non-SP path exactly
# (forward identical); later steps drift only by fp32 reduction order
losses = {}
for sp in (False, True):
    tc = TrainConfig(sync=OptiReduceConfig(strategy="psum", drop_rate=0.0),
                     optimizer=OptimizerConfig(lr=1e-2), seq_chunk=16,
                     seq_parallel=sp)
    make_step, opt, _ = build_train_step(tcfg, tc, mesh2)
    p = init_params(key, tcfg)
    step_fn, sh = make_step(jax.eval_shape(opt.init, p), batch)
    p = jax.device_put(p, sh["params"])
    o = jax.jit(opt.init, out_shardings=sh["opt"])(p)
    b3 = jax.device_put(batch, sh["batch"])
    jf2 = jax.jit(step_fn)
    ls = []
    for i in range(3):
        p, o, m = jf2(p, o, b3, jnp.asarray(i, jnp.int32), key)
        ls.append(float(m["loss"]))
    losses[sp] = ls
assert losses[True][0] == losses[False][0], (losses)    # fwd exact
np.testing.assert_allclose(losses[True], losses[False], rtol=1e-2)
print("seq_parallel OK")
"""


@pytest.mark.slow
def test_perf_paths_multidevice():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", CHILD], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    for marker in ("optireduce_q OK", "rs_wire_q8 OK", "moe_stationary OK",
                   "pure_dp OK", "seq_parallel OK"):
        assert marker in proc.stdout, proc.stdout
