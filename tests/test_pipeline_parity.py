"""Oracle-equivalence (``parity``) suite for the composable collective
pipeline: every registered strategy routed through CollectiveSpec must be
bitwise-identical between the fused ``sync_pytree`` engine — in all three
schedules, ``scan`` / ``vmap`` / the stage-skewed ``pipelined`` software
pipeline (including B=1/B=2, where the skew is deeper than the bucket
count) — and the ``sync_pytree_unfused`` seed-oracle loop on an 8-device
mesh, with drops, kernels, and quantization enabled — plus the 2D
(pod, data) reduce-scatter replica-consistency guarantees.

Runs in ONE subprocess (8 forced host devices, same pattern as
test_collectives.py); the parametrized tests assert per-strategy markers
from its cached output.  Select with ``-m parity``.
"""
import os
import subprocess
import sys

import pytest

# seed names + register_strategy'd cross-product compositions, with the
# knob set each is exercised under: (drop_rate, use_kernels)
STRATEGIES = [
    ("psum", 0.0, False),
    ("gloo_ring", 0.0, False),
    ("nccl_tree", 0.0, False),
    ("bcube", 0.0, False),
    ("tar_tcp", 0.0, True),
    ("tar_rounds", 0.0, False),
    ("optireduce", 0.1, True),
    ("optireduce_2d", 0.1, True),
    ("optireduce_q", 0.05, True),
    ("optireduce_rounds", 0.1, False),
    ("tar_rounds_q", 0.05, True),
    ("ring_ht", 0.0, True),
]

CHILD = r"""
import functools
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, shard_map
from repro.core import (OptiReduceConfig, SyncContext, sync_pytree,
                        sync_pytree_unfused)
from repro.core.allreduce import reduce_scatter_axis, rs_spec

mesh = make_mesh((8,), ("data",))
key = jax.random.PRNGKey(0)
tree = {"w": jax.random.normal(key, (2, 1024)),
        "b": jax.random.normal(jax.random.fold_in(key, 1), (1024,)),
        "v": jax.random.normal(jax.random.fold_in(key, 2), (1024,))}
spec = jax.tree.map(lambda _: P(), tree)
sync_pipelined = functools.partial(sync_pytree, mode="pipelined")

def run(fn, cfg, bucket_elems=1024):
    def body(t):
        ctx = SyncContext(cfg=cfg, key=jax.random.PRNGKey(5))
        out = fn(t, ctx, bucket_elems=bucket_elems)
        return out, ctx.loss_fraction()
    f = jax.jit(shard_map(body, mesh=mesh, in_specs=(spec,),
                          out_specs=(spec, P()), check_vma=False))
    return f(tree)

for item in %(strategies)r:
    strat, dr, uk = item
    cfg = OptiReduceConfig(strategy=strat, drop_rate=dr, hadamard_block=256,
                           use_kernels=uk, quant_bits=8, incast=3)
    ref, ref_frac = run(sync_pytree_unfused, cfg)
    out, out_frac = run(sync_pytree, cfg)
    for k in tree:
        assert np.array_equal(np.asarray(ref[k]), np.asarray(out[k])), \
            (strat, k)
    np.testing.assert_allclose(float(ref_frac), float(out_frac), atol=1e-6)
    print("PARITY %%s OK loss_frac=%%.4f" %% (strat, float(out_frac)))
    # every engine schedule must hit the same bits: vmap (batched
    # collectives) and the stage-skewed software pipeline (B=4 here:
    # prologue + a 2-step lax.scan steady state + epilogue all execute)
    for mode in ("vmap", "pipelined"):
        alt, alt_frac = run(functools.partial(sync_pytree, mode=mode), cfg)
        for k in tree:
            assert np.array_equal(np.asarray(ref[k]), np.asarray(alt[k])), \
                (mode, strat, k)
        np.testing.assert_allclose(float(ref_frac), float(alt_frac),
                                   atol=1e-6)
        print("MODE %%s %%s OK" %% (mode, strat))
    print("PIPELINED %%s OK" %% strat)

# ---- skew deeper than the bucket count: B=1 and B=2 edge cases -----------
# (tree total is 4096, so bucket_elems 4096/2048 give full tail buckets and
# the quantized strategies stay bitwise vs the oracle)
for strat, dr, uk in (("optireduce", 0.1, True),
                      ("optireduce_q", 0.05, True),
                      ("optireduce_rounds", 0.1, False)):
    cfg = OptiReduceConfig(strategy=strat, drop_rate=dr, hadamard_block=256,
                           use_kernels=uk, quant_bits=8, incast=3)
    for be, nb in ((4096, 1), (2048, 2)):
        ref, ref_frac = run(sync_pytree_unfused, cfg, bucket_elems=be)
        for fn in (sync_pytree, sync_pipelined):
            out, out_frac = run(fn, cfg, bucket_elems=be)
            for k in tree:
                assert np.array_equal(np.asarray(ref[k]),
                                      np.asarray(out[k])), (strat, be, k)
            np.testing.assert_allclose(float(ref_frac), float(out_frac),
                                       atol=1e-6)
    print("PIPELINE_EDGE %%s OK" %% strat)

# ---- policy-driven dispatch: a full active set is a bitwise no-op --------
# (the acceptance pin for the runtime control plane: with no stragglers
# detected the SyncPolicy names every peer, active_subset normalizes that
# to None, and every strategy stays on the exact full-participation trace)
import dataclasses
from repro.runtime import SyncPolicy
for item in %(strategies)r:
    strat, dr, uk = item
    cfg = OptiReduceConfig(strategy=strat, drop_rate=dr, hadamard_block=256,
                           use_kernels=uk, quant_bits=8, incast=3)
    policy = SyncPolicy(use_hadamard=cfg.use_hadamard, incast=cfg.incast,
                        active_peers=tuple(range(8)),
                        shard_weights=(4,) * 8, dead_links=())
    ref, ref_frac = run(sync_pytree, cfg)
    out, out_frac = run(sync_pytree, policy.apply(cfg))
    for k in tree:
        assert np.array_equal(np.asarray(ref[k]), np.asarray(out[k])), \
            ("policy", strat, k)
    np.testing.assert_allclose(float(ref_frac), float(out_frac), atol=1e-6)
    print("POLICY_FULLSET %%s OK" %% strat)

# ---- degraded participation: ejected peers excluded, replicas bitwise ----
# per-node distinct gradients (scaled by 1 + peer id) so exclusion is
# visible; with drop_rate=0 the synced value must equal the mean over the
# ACTIVE peers' contributions exactly (up to codec noise for quantizers)
ACTIVE = (0, 1, 2, 4, 5, 7)
xflat = jax.random.normal(key, (4096,))
def run_scaled(cfg):
    def body(xx):
        i = jax.lax.axis_index("data")
        local = {"w": xx * (1.0 + i.astype(jnp.float32))}
        ctx = SyncContext(cfg=cfg, key=jax.random.PRNGKey(5))
        out = sync_pytree(local, ctx, bucket_elems=1024)
        return out["w"][None], ctx.loss_fraction()[None]
    f = jax.jit(shard_map(body, mesh=mesh, in_specs=(P(),),
                          out_specs=(P("data"), P("data")), check_vma=False))
    return f(xflat)

expected = np.asarray(xflat) * np.mean([1.0 + p for p in ACTIVE])
for strat, uk, tol in (("optireduce", True, 1e-4),      # a2a: mask exclusion
                       ("optireduce_rounds", False, 1e-4),  # subset schedule
                       ("ring_ht", False, 1e-4),        # virtual ring
                       ("optireduce_q", True, 5e-2)):   # quantized subset
    cfg = OptiReduceConfig(strategy=strat, drop_rate=0.0, hadamard_block=256,
                           use_kernels=uk, quant_bits=8, incast=3,
                           active_peers=ACTIVE)
    out, _ = run_scaled(cfg)
    out = np.asarray(out)
    assert np.array_equal(out, np.broadcast_to(out[0:1], out.shape)), \
        ("participation replica divergence", strat)
    err = np.max(np.abs(out[0] - expected)) / np.max(np.abs(expected))
    assert err < tol, (strat, err)
    # with transport drops on top, replicas must still agree bitwise
    if strat != "ring_ht":                       # ring rejects Lossy
        cfgd = dataclasses.replace(cfg, drop_rate=0.1)
        outd, _ = run_scaled(cfgd)
        outd = np.asarray(outd)
        assert np.array_equal(outd, np.broadcast_to(outd[0:1], outd.shape)), \
            ("participation+drops divergence", strat)
    print("PARTICIPATION %%s OK err=%%.2e" %% (strat, err))

# the subset round schedule must genuinely shrink: 2(A-1) rounds + 1 graft
# vs 2(N-1) collective-permute sites in the lowered HLO
def _n_perms(cfg):
    def body(xx):
        ctx = SyncContext(cfg=cfg, key=jax.random.PRNGKey(5))
        return sync_pytree({"w": xx}, ctx, bucket_elems=4096)["w"]
    f = jax.jit(shard_map(body, mesh=mesh, in_specs=(P(),),
                          out_specs=P(), check_vma=False))
    return f.lower(xflat).as_text().count("stablehlo.collective_permute")
full_perms = _n_perms(OptiReduceConfig(strategy="optireduce_rounds",
                                       incast=1, hadamard_block=256))
sub_perms = _n_perms(OptiReduceConfig(strategy="optireduce_rounds",
                                      incast=1, hadamard_block=256,
                                      active_peers=ACTIVE))
assert full_perms == 14, full_perms              # 2*(8-1)
assert sub_perms == 11, sub_perms                # 2*(6-1) + 1 graft
print("PARTICIPATION_SCHEDULE OK %%d -> %%d" %% (full_perms, sub_perms))

# ---- weighted shards: straggler-proportional ownership, same bits --------
# a non-uniform plan re-cuts the bucket into weight-proportional contiguous
# slices; at drop 0 the masked mean reduces the SAME elements in the SAME
# row order, so both rounds strategies must stay bitwise vs uniform
for strat in ("tar_rounds", "optireduce_rounds"):
    cfg0 = OptiReduceConfig(strategy=strat, drop_rate=0.0,
                            hadamard_block=256, incast=3)
    ref, _ = run(sync_pytree, cfg0)
    out, _ = run(sync_pytree,
                 dataclasses.replace(cfg0, shard_weights=(2,) * 7 + (1,)))
    for k in tree:
        assert np.array_equal(np.asarray(ref[k]), np.asarray(out[k])), \
            ("weighted", strat, k)
    print("WEIGHTED %%s OK" %% strat)

# weighted composes with a degraded active set: 6 peers, the last one at
# half weight, distinct per-node gradients — replicas bitwise-identical and
# the synced value is exactly the mean over the ACTIVE contributions
cfgws = OptiReduceConfig(strategy="optireduce_rounds", drop_rate=0.0,
                         hadamard_block=256, incast=3, active_peers=ACTIVE,
                         shard_weights=(2, 2, 2, 2, 2, 1))
outs, _ = run_scaled(cfgws)
outs = np.asarray(outs)
assert np.array_equal(outs, np.broadcast_to(outs[0:1], outs.shape)), \
    "weighted subset replica divergence"
errw = np.max(np.abs(outs[0] - expected)) / np.max(np.abs(expected))
assert errw < 1e-4, errw
print("WEIGHTED_SUBSET OK err=%%.2e" %% errw)

# ---- dead-link rewiring: relayed rounds, same bits -----------------------
# a dead directed edge reroutes that round's transfer through a 2-hop relay
# instead of ejecting the endpoint; unnamed ppermute destinations receive
# zeros and recv = direct + relayed, so the received matrix — and with it
# the arrival-mask PRNG stream — is unchanged: bitwise even UNDER drops
cfg0 = OptiReduceConfig(strategy="optireduce_rounds", drop_rate=0.1,
                        hadamard_block=256, incast=3)
ref, ref_frac = run(sync_pytree, cfg0)
out, out_frac = run(sync_pytree,
                    dataclasses.replace(cfg0, dead_links=((2, 5),)))
for k in tree:
    assert np.array_equal(np.asarray(ref[k]), np.asarray(out[k])), \
        ("deadlink", k)
np.testing.assert_allclose(float(ref_frac), float(out_frac), atol=1e-6)
# ...and composes with weighted shards (pinned to weighted-only bits)
cfgw1 = dataclasses.replace(cfg0, shard_weights=(2,) * 7 + (1,))
refw, _ = run(sync_pytree, cfgw1)
outwd, _ = run(sync_pytree,
               dataclasses.replace(cfgw1, dead_links=((2, 5),)))
for k in tree:
    assert np.array_equal(np.asarray(refw[k]), np.asarray(outwd[k])), \
        ("weighted+deadlink", k)
# the relay is really in the lowered schedule: 2 extra permute sites per
# stage (src->relay, relay->dst) on top of the 2(N-1) round permutes
dead_perms = _n_perms(OptiReduceConfig(strategy="optireduce_rounds",
                                       incast=1, hadamard_block=256,
                                       dead_links=((2, 5),)))
assert dead_perms == 18, dead_perms              # 14 + 2 relays * 2 stages
print("DEADLINK OK %%d perms" %% dead_perms)

# ---- 2D (pod, data) reduce-scatter: cross-pod replica consistency --------
mesh2 = make_mesh((2, 4), ("pod", "data"))
g = jax.random.normal(key, (4, 64, 48))        # same gradient on every node
cfg2 = OptiReduceConfig(drop_rate=0.05, pod_axis="pod", hadamard_block=256,
                        rs_wire_bits=8, use_kernels=True)

def rs_body(x):
    ctx = SyncContext(cfg=cfg2, key=jax.random.PRNGKey(1))
    i = jax.lax.axis_index("data")
    local = jnp.take(x, i, axis=0)             # pod-replicated input
    return reduce_scatter_axis(local, "data", 0, ctx)[None]
f2 = jax.jit(shard_map(rs_body, mesh=mesh2, in_specs=P(None, None, None),
                       out_specs=P(("pod", "data"), None, None),
                       check_vma=False))
out2 = np.asarray(f2(g))                       # (8, 16, 48): pod-major rows
assert np.array_equal(out2[:4], out2[4:]), \
    np.max(np.abs(out2[:4] - out2[4:]))
print("RS2D replica-consistency OK")

# the quantization grids themselves must be pmax-shared across pods (not
# just the inner axis) when a pod axis is configured: encode with inputs
# that VARY per pod and check every node derives identical grids
enc_codec = rs_spec(cfg2).codec
def grid_body(x):
    ctx = SyncContext(cfg=cfg2, key=jax.random.PRNGKey(1))
    p = jax.lax.axis_index("pod")
    local = x * (1.0 + p)                      # pod-dependent scale
    return enc_codec.encode(local.reshape(-1), ctx, "data").lo[None]
f3 = jax.jit(shard_map(grid_body, mesh=mesh2, in_specs=P(None),
                       out_specs=P(("pod", "data"), None), check_vma=False))
lo = np.asarray(f3(jax.random.normal(key, (2048,))))
assert np.all(lo == lo[0:1]), "quant grids differ across pods"
print("RS2D grids-shared OK")
"""


@pytest.fixture(scope="module")
def parity_output():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", CHILD % {"strategies": STRATEGIES}],
        env=env, capture_output=True, text=True, timeout=1800)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


@pytest.mark.parity
@pytest.mark.slow
@pytest.mark.parametrize("strategy,drop_rate,use_kernels", STRATEGIES)
def test_spec_bitwise_matches_seed_oracle(parity_output, strategy, drop_rate,
                                          use_kernels):
    assert f"PARITY {strategy} OK" in parity_output, parity_output


@pytest.mark.parity
@pytest.mark.slow
@pytest.mark.parametrize("strategy,drop_rate,use_kernels", STRATEGIES)
def test_pipelined_mode_bitwise(parity_output, strategy, drop_rate,
                                use_kernels):
    """Every engine schedule — mode='vmap' and the stage-skewed software
    pipeline (mode='pipelined') — is pinned bitwise to mode='scan' and the
    sync_pytree_unfused oracle for every registered strategy on 8 devices,
    drops + kernels + quantized exchange included."""
    assert f"MODE vmap {strategy} OK" in parity_output, parity_output
    assert f"MODE pipelined {strategy} OK" in parity_output, parity_output
    assert f"PIPELINED {strategy} OK" in parity_output, parity_output


@pytest.mark.parity
@pytest.mark.slow
@pytest.mark.parametrize("strategy",
                         ["optireduce", "optireduce_q", "optireduce_rounds"])
def test_pipelined_skew_deeper_than_bucket_count(parity_output, strategy):
    """B=1 and B=2 edge cases: the depth-2 skew exceeds the bucket count, so
    the whole schedule unrolls into prologue/epilogue — still bitwise vs the
    oracle and scan mode."""
    assert f"PIPELINE_EDGE {strategy} OK" in parity_output, parity_output


@pytest.mark.parity
@pytest.mark.slow
@pytest.mark.parametrize("strategy,drop_rate,use_kernels", STRATEGIES)
def test_policy_full_set_is_bitwise_noop(parity_output, strategy, drop_rate,
                                         use_kernels):
    """Acceptance: policy-driven dispatch with a full active-peer set (no
    stragglers detected), UNIFORM shard weights, and no dead links keeps
    every registered strategy bitwise-identical to its current output —
    SyncPolicy.apply naming all 8 peers at equal weight normalizes to the
    exact full-participation uniform-shard trace."""
    assert f"POLICY_FULLSET {strategy} OK" in parity_output, parity_output


@pytest.mark.parity
@pytest.mark.slow
@pytest.mark.parametrize("strategy", ["tar_rounds", "optireduce_rounds"])
def test_weighted_shards_bitwise(parity_output, strategy):
    """Straggler-proportional shard weights on the rounds schedules: a
    non-uniform plan (one peer at half weight) re-cuts ownership but stays
    bitwise-identical to the uniform exchange at zero drops, and composes
    with a degraded active set (replica-consistent, exact active mean)."""
    assert f"WEIGHTED {strategy} OK" in parity_output, parity_output
    assert "WEIGHTED_SUBSET OK" in parity_output, parity_output


@pytest.mark.parity
@pytest.mark.slow
def test_dead_link_rewiring_bitwise(parity_output):
    """Link-fault rewiring: a dead (2, 5) edge relays through a live peer
    — bitwise-identical output even under transport drops (alone and
    stacked on weighted shards), with the 2-hop relay visible as 2 extra
    collective-permute sites per stage in the lowered HLO (14 -> 18)."""
    assert "DEADLINK OK 18 perms" in parity_output, parity_output


@pytest.mark.parity
@pytest.mark.slow
@pytest.mark.parametrize("strategy", ["optireduce", "optireduce_rounds",
                                      "ring_ht", "optireduce_q"])
def test_degraded_participation_semantics(parity_output, strategy):
    """Degraded participation on 8 devices: ejected peers' contributions
    are excluded (the synced bucket equals the mean over ACTIVE peers'
    distinct gradients), replicas stay bitwise-identical — including the
    ejected peers, which still receive the result — and transport drops
    compose with the exclusion."""
    assert f"PARTICIPATION {strategy} OK" in parity_output, parity_output


@pytest.mark.parity
@pytest.mark.slow
def test_degraded_round_schedule_shrinks(parity_output):
    """The rounds schedule is regenerated over the active set: 2(A-1)
    collective-permute sites plus one graft round in the lowered HLO,
    against 2(N-1) at full participation."""
    assert "PARTICIPATION_SCHEDULE OK 14 -> 11" in parity_output, \
        parity_output


@pytest.mark.parity
@pytest.mark.slow
def test_reduce_scatter_2d_replica_consistent(parity_output):
    """Satellite: quantized reduce_scatter on a (pod, data) mesh — pod-
    replicated inputs reduce to bitwise-identical shards in every pod, and
    the shared quantization grids are pmax'd across pods, not just the
    inner axis."""
    assert "RS2D replica-consistency OK" in parity_output, parity_output
    assert "RS2D grids-shared OK" in parity_output, parity_output
